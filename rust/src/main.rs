//! `codesign` — CLI for the hardware/software co-design framework.
//!
//! Subcommands (see README.md):
//!   quickstart                 evaluate Eyeriss + a searched mapping on DQN-K2
//!   sw-opt                     software mapping search on fixed hardware
//!   codesign                   full nested co-design on a model
//!   schedule                   concurrent co-design jobs over several models
//!                              (one scheduler, shared cache + certificates
//!                              + semi-decoupled mapping tables)
//!   transfer                   co-design warm-started from a prior run's
//!                              checkpoint (--source-checkpoint PATH)
//!   fig3|fig4|fig5a|fig5b|fig5c|fig16|fig17|fig18|insight
//!                              regenerate the paper's figures (CSV under results/)
//!   trace summarize|diff       render or compare run-trace journals
//!   selftest                   artifact <-> native GP numerical cross-check
//!
//! Common flags: --model NAME --layer NAME --trials N --hw-trials N
//!   --sw-trials N --repeats N --scale F --seed N --threads N --out DIR
//!   --method M --native (use the pure-Rust GP instead of the PJRT artifacts)
//!   --cache-policy slru|fifo --cache-snapshot PATH (codesign: persist the
//!   evaluation cache across runs and warm-start from a prior run)
//!
//! Observability (see rust/src/obs/README.md): --trace PATH (codesign) /
//!   --trace-dir DIR (schedule) write per-run JSONL journals, deterministic
//!   unless --trace-wall adds wall-clock data; --metrics-addr HOST:PORT
//!   serves the fleet's Prometheus exposition while a schedule runs;
//!   --metrics-out PATH dumps the final exposition to a file.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use codesign::coordinator::checkpoint::Checkpoint;
use codesign::coordinator::driver::{eyeriss_baseline, Driver};
use codesign::coordinator::run::{JobSpec, SearchStrategy};
use codesign::figures::{fig3, fig4, fig5a, fig5bc, insight, FigOpts};
use codesign::model::cache::{CachePolicy, EvalCache, DEFAULT_CAPACITY, DEFAULT_SHARDS};
use codesign::model::eval::Evaluator;
use codesign::obs::clock::Stopwatch;
use codesign::obs::trace::{self as trace_journal, TraceConfig};
use codesign::opt::config::{BoConfig, NestedConfig, SemiDecoupledConfig};
use codesign::opt::hw_search::{HwMethod, HwTrace};
use codesign::opt::sw_search::{search, SurrogateKind, SwMethod, SwProblem};
use codesign::opt::transfer::TransferPrior;
use codesign::runtime::jobs::JobScheduler;
use codesign::runtime::server::{GpServer, MetricsServer};
use codesign::space::sw_space::SwSpace;
use codesign::surrogate::gp::GpBackend;
use codesign::util::rng::Rng;
use codesign::workloads::eyeriss::{eyeriss_hw, eyeriss_resources};
use codesign::workloads::specs::{layer_by_name, model_by_name};

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
    bools: Vec<String>,
    /// Positional operands after the subcommand (e.g. journal paths for
    /// `trace summarize` / `trace diff`), in order.
    pos: Vec<String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut pos = Vec::new();
        let mut pending: Option<String> = None;
        for tok in it {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some(p) = pending.take() {
                    bools.push(p);
                }
                pending = Some(name.to_string());
            } else if let Some(name) = pending.take() {
                flags.insert(name, tok);
            } else {
                pos.push(tok);
            }
        }
        if let Some(p) = pending.take() {
            bools.push(p);
        }
        Ok(Args { cmd, flags, bools, pos })
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v}: {e}")),
            None => Ok(default),
        }
    }

    fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

/// Choose the GP backend: PJRT artifacts unless --native.
fn backend(args: &Args) -> Result<(GpBackend, Option<GpServer>)> {
    if args.bool("native") {
        return Ok((GpBackend::Native, None));
    }
    match GpServer::start() {
        Ok(server) => {
            let h = server.handle();
            Ok((GpBackend::Aot(h), Some(server)))
        }
        Err(e) => bail!(
            "failed to start the PJRT GP server: {e:#}\n\
             run `make artifacts` first, or pass --native for the pure-Rust GP"
        ),
    }
}

/// Parse `--strategy` (plus its semi-decoupled knobs) into the outer-loop
/// strategy a job spec carries.
fn strategy(args: &Args) -> Result<SearchStrategy> {
    Ok(match args.str("strategy", "nested").as_str() {
        "nested" => SearchStrategy::Nested,
        "semi-decoupled" => {
            let d = SemiDecoupledConfig::default();
            SearchStrategy::SemiDecoupled(SemiDecoupledConfig {
                max_cells: args.get("table-cells", d.max_cells)?,
                cell_sw_trials: args.get("cell-sw-trials", d.cell_sw_trials)?,
                topk: args.get("topk", d.topk)?,
                ..d
            })
        }
        other => bail!("unknown strategy {other} (expected nested|semi-decoupled)"),
    })
}

fn sw_method(name: &str) -> Result<SwMethod> {
    Ok(match name {
        "bo" | "bo-gp" => SwMethod::Bo { surrogate: SurrogateKind::Gp },
        "bo-rf" => SwMethod::Bo { surrogate: SurrogateKind::RandomForest },
        "random" => SwMethod::Random,
        "round-bo" => SwMethod::RoundBo,
        "tvm-xgb" => SwMethod::TvmXgb,
        "tvm-treegru" => SwMethod::TvmTreeGru,
        other => bail!("unknown software method {other}"),
    })
}

fn fig_opts(args: &Args, backend: GpBackend) -> Result<FigOpts> {
    let mut opts = FigOpts::new(backend);
    opts.scale = args.get("scale", 1.0)?;
    opts.repeats = args.get("repeats", 0usize)?;
    opts.seed = args.get("seed", 2020u64)?;
    opts.threads = args.get("threads", codesign::coordinator::parallel::default_threads())?;
    opts.out_dir = args.str("out", "results").into();
    Ok(opts)
}

fn cmd_quickstart(args: &Args) -> Result<()> {
    let (backend, _server) = backend(args)?;
    let layer_name = args.str("layer", "DQN-K2");
    let layer = layer_by_name(&layer_name).context("unknown layer")?;
    let num_pes = if layer_name.starts_with("Transformer") { 256 } else { 168 };
    let hw = eyeriss_hw(num_pes);
    let res = eyeriss_resources(num_pes);
    let eval = Evaluator::new(res.clone());

    println!("== codesign quickstart ==");
    println!("layer {layer_name}: {layer:?}");
    println!("{}", insight::describe_hw("hardware (Eyeriss)", &hw));

    let problem = SwProblem::new(SwSpace::new(layer.clone(), hw.clone(), res), eval.clone());
    let trials = args.get("trials", 100usize)?;
    let mut rng = Rng::seed_from_u64(args.get("seed", 0u64)?);
    let trace = search(
        SwMethod::Bo { surrogate: SurrogateKind::Gp },
        &problem,
        trials,
        &BoConfig::software(),
        &backend,
        &mut rng,
    );
    let best = trace.best_mapping.clone().context("no feasible mapping found")?;
    let met = eval.evaluate(&layer, &hw, &best).unwrap();
    println!("\nbest mapping after {trials} BO trials:");
    println!("  {}", best.describe());
    println!("\nmetrics:");
    println!("  EDP            {:.4e} J*s", met.edp);
    println!(
        "  energy         {:.4e} pJ  (mac/spad/glb/noc/dram = {:?})",
        met.energy_pj, met.energy_breakdown
    );
    println!("  cycles         {:.4e}  (bottleneck: {})", met.cycles, met.bottleneck());
    println!("  PE utilization {:.1}%", met.utilization * 100.0);
    println!(
        "  roofline gap   {:.1}x (EDP / analytic lower bound)",
        met.edp
            / codesign::model::energy::roofline_edp(&layer, &eval.resources, &eval.energy_model)
    );
    Ok(())
}

fn cmd_sw_opt(args: &Args) -> Result<()> {
    let (backend, _server) = backend(args)?;
    let layer = args.str("layer", "DQN-K2");
    let method = sw_method(&args.str("method", "bo"))?;
    let trials = args.get("trials", 250usize)?;
    let problem = fig3::problem_for(&layer);
    let mut rng = Rng::seed_from_u64(args.get("seed", 0u64)?);
    let t0 = Stopwatch::start();
    let trace = search(method, &problem, trials, &BoConfig::software(), &backend, &mut rng);
    println!(
        "{layer} {}: best EDP {:.4e} after {} trials ({} raw draws, {:.1}s)",
        method.name(),
        trace.best_edp,
        trace.evals.len(),
        trace.raw_draws,
        t0.elapsed().as_secs_f64()
    );
    if let Some(m) = &trace.best_mapping {
        println!("mapping: {}", m.describe());
    }
    Ok(())
}

fn cmd_codesign(args: &Args) -> Result<()> {
    let (backend, _server) = backend(args)?;
    let model_name = args.str("model", "dqn");
    let model = model_by_name(&model_name).context("unknown model")?;
    let ncfg = NestedConfig {
        hw_trials: args.get("hw-trials", 50usize)?,
        sw_trials: args.get("sw-trials", 250usize)?,
        hw_bo: BoConfig::hardware(),
        sw_bo: BoConfig::software(),
    };
    let mut driver = Driver::new(ncfg);
    driver.threads = args.get("threads", codesign::coordinator::parallel::default_threads())?;
    driver.sw_method = sw_method(&args.str("method", "bo"))?;
    driver.strategy = strategy(args)?;
    driver.hw_method = match args.str("hw-method", "bo").as_str() {
        "bo" => HwMethod::Bo,
        "bo-rf" => HwMethod::BoRf,
        "random" => HwMethod::Random,
        other => bail!("unknown hardware method {other}"),
    };
    let out_dir: std::path::PathBuf = args.str("out", "results").into();
    driver.checkpoint_path = Some(out_dir.join(format!("best_design_{model_name}.txt")));

    // Evaluation-cache policy and cross-run persistence.
    let policy_name = args.str("cache-policy", "slru");
    let policy = CachePolicy::parse(&policy_name)
        .ok_or_else(|| anyhow!("unknown cache policy {policy_name} (expected slru|fifo)"))?;
    let cache = EvalCache::with_policy(policy, DEFAULT_SHARDS, DEFAULT_CAPACITY);
    driver.cache = std::sync::Arc::new(cache);
    if let Some(p) = args.flags.get("cache-snapshot") {
        driver.cache_snapshot_path = Some(p.into());
    }
    if let Some(p) = args.flags.get("trace") {
        driver.trace = Some(TraceConfig::new(p, !args.bool("trace-wall")));
    }

    let seed = args.get("seed", 2020u64)?;
    println!(
        "{} co-design on {model_name}: {} hw x {} sw trials, {} threads, \
         cache policy {}{}",
        match &driver.strategy {
            SearchStrategy::Nested => "nested",
            SearchStrategy::SemiDecoupled(_) => "semi-decoupled",
            SearchStrategy::Transfer(_) => "transfer",
        },
        driver.ncfg.hw_trials,
        driver.ncfg.sw_trials,
        driver.threads,
        policy.name(),
        driver
            .cache_snapshot_path
            .as_ref()
            .map(|p| format!(", snapshot {}", p.display()))
            .unwrap_or_default()
    );

    let base = eyeriss_baseline(
        &model,
        driver.sw_method,
        driver.ncfg.sw_trials,
        &backend,
        driver.threads,
        seed,
    );
    let out = driver.run(&model, &backend, seed + 1);

    println!("\n== result ==\n{}", out.metrics.report());
    match (&out.best, base) {
        (Some(best), Some((eyeriss_edp, _))) => {
            let searched = best.best_edp.min(eyeriss_edp);
            println!("{}", insight::describe_hw("searched hardware", &best.hw));
            for (name, m, edp) in &best.layers {
                println!("  {name}: EDP {edp:.4e}  {}", m.describe());
            }
            println!("\nEyeriss baseline EDP : {eyeriss_edp:.4e}");
            println!("searched design EDP  : {searched:.4e}");
            println!(
                "improvement          : {:.1}% (paper: 40.2% DQN / 18.3% ResNet / 21.8% MLP / 16.0% Transformer)",
                (1.0 - searched / eyeriss_edp) * 100.0
            );
        }
        _ => println!("no feasible design found under the given budget"),
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let (backend, _server) = backend(args)?;
    let models_arg = args.str("models", "dqn,mlp");
    let names: Vec<&str> = models_arg.split(',').filter(|s| !s.is_empty()).collect();
    if names.is_empty() {
        bail!("--models must name at least one model");
    }
    let ncfg = NestedConfig {
        hw_trials: args.get("hw-trials", 20usize)?,
        sw_trials: args.get("sw-trials", 100usize)?,
        hw_bo: BoConfig::hardware(),
        sw_bo: BoConfig::software(),
    };
    let sw = sw_method(&args.str("method", "bo"))?;
    let strat = strategy(args)?;
    let threads = args.get("threads", codesign::coordinator::parallel::default_threads())?;
    let seed = args.get("seed", 2020u64)?;
    let max_jobs = args.get("jobs", 0usize)?;
    let out_dir: std::path::PathBuf = args.str("out", "results").into();
    let _ = std::fs::create_dir_all(&out_dir);
    let trace_dir = args.flags.get("trace-dir").map(std::path::PathBuf::from);
    if let Some(d) = &trace_dir {
        std::fs::create_dir_all(d)
            .with_context(|| format!("creating trace dir {}", d.display()))?;
    }

    println!(
        "scheduling {} co-design jobs ({} hw x {} sw trials each, {threads} threads/job, {})",
        names.len(),
        ncfg.hw_trials,
        ncfg.sw_trials,
        if max_jobs == 0 { "unbounded".to_string() } else { format!("<= {max_jobs} at once") }
    );

    let sched = JobScheduler::with_capacity(backend, max_jobs);
    let _metrics_server = match args.flags.get("metrics-addr") {
        Some(addr) => {
            let server = MetricsServer::start(
                addr,
                std::sync::Arc::clone(sched.fleet()),
                std::sync::Arc::clone(sched.cache()),
                std::sync::Arc::clone(sched.certificate_store()),
            )?;
            println!("fleet metrics exposition at http://{}/metrics", server.addr());
            Some(server)
        }
        None => None,
    };
    let mut handles = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let model = model_by_name(name).with_context(|| format!("unknown model {name}"))?;
        let mut spec = JobSpec::new(model, ncfg.clone(), seed + i as u64);
        spec.sw_method = sw;
        // one strategy for the whole schedule: semi-decoupled jobs sharing
        // a model then share one phase-1 mapping table via the scheduler
        spec.strategy = strat.clone();
        spec.threads = threads;
        spec.checkpoint_path = Some(out_dir.join(format!("best_design_{name}.txt")));
        if let Some(d) = &trace_dir {
            let path = d.join(format!("TRACE_{name}.jsonl"));
            spec.trace = Some(TraceConfig::new(path, !args.bool("trace-wall")));
        }
        handles.push((name.to_string(), sched.submit(spec)));
    }

    loop {
        let mut line = String::new();
        let mut all_done = true;
        for (name, handle) in &handles {
            let p = handle.progress();
            all_done &= handle.is_finished();
            line.push_str(&format!(
                "[{name}: {} {}/{}] ",
                p.phase.name(),
                p.trials_done,
                p.trials_total
            ));
        }
        println!("{}", line.trim_end());
        if all_done {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1500));
    }

    for (name, handle) in handles {
        let out = handle.wait();
        println!("\n== {name} ==\n{}", out.metrics.report());
        match &out.best {
            Some(best) => {
                println!("{}", insight::describe_hw("searched hardware", &best.hw));
                println!("best model EDP: {:.4e} (trial {})", best.best_edp, best.trial);
            }
            None => println!("no feasible design found under the given budget"),
        }
    }
    let stats = sched.cache().stats();
    println!(
        "\nshared cache after all jobs: {} entries, {} hits / {} misses; \
         {} prune certificates memoized across jobs",
        stats.entries,
        stats.hits,
        stats.misses,
        sched.certificate_store().len()
    );
    let fleet = sched.fleet();
    println!(
        "fleet: {} jobs completed ({} cancelled), {} simulator evals / {} raw draws total",
        fleet.jobs_completed(),
        fleet.jobs_cancelled(),
        fleet.counter("sim_evals"),
        fleet.counter("raw_draws"),
    );
    if let Some(d) = &trace_dir {
        println!("trace journals under {} (render with `codesign trace summarize`)", d.display());
    }
    if let Some(p) = args.flags.get("metrics-out") {
        std::fs::write(p, sched.fleet_exposition())
            .with_context(|| format!("writing metrics exposition to {p}"))?;
        println!("wrote fleet metrics exposition to {p}");
    }
    Ok(())
}

/// `codesign transfer --model M --source-checkpoint PATH`: a co-design run
/// whose surrogates are warm-started from a prior run's persisted incumbent
/// (`best_design_*.txt`). The checkpoint yields a one-point prior — the
/// source run's best (hardware, EDP) — which seeds the objective GP and the
/// feasibility classifier; the job routes through the scheduler like every
/// other run, so it shares cache/certificate/table state with any jobs
/// scheduled beside it.
fn cmd_transfer(args: &Args) -> Result<()> {
    let (backend, _server) = backend(args)?;
    let model_name = args.str("model", "dqn");
    let model = model_by_name(&model_name).context("unknown model")?;
    let ckpt_path = args
        .flags
        .get("source-checkpoint")
        .context("transfer needs --source-checkpoint PATH (a best_design_*.txt from a prior run)")?;
    let ck = Checkpoint::load(std::path::Path::new(ckpt_path))
        .with_context(|| format!("loading source checkpoint {ckpt_path}"))?;
    // Synthesize the source trace the prior is extracted from: a checkpoint
    // persists only the incumbent, so the prior carries one feasible point.
    // (With fewer than two prior observations the search keeps its random
    // warmup — the prior still seeds both surrogates.)
    let mut source = HwTrace::new();
    source.record(&ck.hw, Some(ck.best_edp));
    let prior = TransferPrior::from_trace(&source);

    let ncfg = NestedConfig {
        hw_trials: args.get("hw-trials", 20usize)?,
        sw_trials: args.get("sw-trials", 100usize)?,
        hw_bo: BoConfig::hardware(),
        sw_bo: BoConfig::software(),
    };
    let out_dir: std::path::PathBuf = args.str("out", "results").into();
    let _ = std::fs::create_dir_all(&out_dir);
    let mut spec = JobSpec::new(model, ncfg, args.get("seed", 2020u64)?);
    spec.sw_method = sw_method(&args.str("method", "bo"))?;
    spec.strategy = SearchStrategy::Transfer(prior);
    spec.threads = args.get("threads", codesign::coordinator::parallel::default_threads())?;
    spec.checkpoint_path = Some(out_dir.join(format!("best_design_{model_name}.txt")));
    if let Some(p) = args.flags.get("trace") {
        spec.trace = Some(TraceConfig::new(p, !args.bool("trace-wall")));
    }

    println!(
        "transfer co-design on {model_name}: prior from {} (source model {}, EDP {:.4e}), \
         {} hw x {} sw trials",
        ckpt_path, ck.model, ck.best_edp, spec.ncfg.hw_trials, spec.ncfg.sw_trials
    );
    let sched = JobScheduler::with_capacity(backend, 1);
    let out = sched.submit(spec).wait();
    println!("\n== result ==\n{}", out.metrics.report());
    match &out.best {
        Some(best) => {
            println!("{}", insight::describe_hw("searched hardware", &best.hw));
            println!("best model EDP: {:.4e} (trial {})", best.best_edp, best.trial);
            println!(
                "vs source incumbent: {:.1}%",
                (1.0 - best.best_edp / ck.best_edp) * 100.0
            );
        }
        None => println!("no feasible design found under the given budget"),
    }
    Ok(())
}

/// `codesign trace summarize <journal>` / `codesign trace diff <a> <b>`:
/// render a run-trace journal written by `--trace`/`--trace-dir`, or compare
/// two journals after stripping wall-clock-only fields (see obs::trace).
fn cmd_trace(args: &Args) -> Result<()> {
    let journal = |p: &String| -> Result<Vec<codesign::obs::json::Json>> {
        trace_journal::load_journal(std::path::Path::new(p)).map_err(|e| anyhow!(e))
    };
    match args.pos.first().map(String::as_str) {
        Some("summarize") => {
            let path =
                args.pos.get(1).context("usage: codesign trace summarize <journal.jsonl>")?;
            print!("{}", trace_journal::summarize(&journal(path)?));
            Ok(())
        }
        Some("diff") => {
            let a = args.pos.get(1).context("usage: codesign trace diff <a.jsonl> <b.jsonl>")?;
            let b = args.pos.get(2).context("usage: codesign trace diff <a.jsonl> <b.jsonl>")?;
            let (ea, eb) = (journal(a)?, journal(b)?);
            let drift = trace_journal::diff(&ea, &eb);
            if drift.is_empty() {
                println!("journals match ({} events, wall-clock fields ignored)", ea.len());
                return Ok(());
            }
            for line in &drift {
                println!("{line}");
            }
            bail!("{} divergence(s) between {a} and {b}", drift.len())
        }
        _ => bail!("usage: codesign trace <summarize|diff> <journal.jsonl> [other.jsonl]"),
    }
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let (backend, _server) = backend(args)?;
    let GpBackend::Aot(handle) = &backend else {
        bail!("selftest needs the PJRT artifacts (omit --native)");
    };
    let mut rng = Rng::seed_from_u64(1);
    let n = 40;
    let x: Vec<Vec<f64>> =
        (0..n).map(|_| (0..16).map(|_| rng.normal() * 0.4).collect()).collect();
    let y: Vec<f64> = x.iter().map(|xi| xi.iter().sum::<f64>()).collect();
    let theta = codesign::runtime::gp_exec::Theta::hw_default();
    let native = codesign::surrogate::gp_native::NativeGp::fit(theta, &x, &y)
        .context("native fit failed")?;
    let aot = handle.posterior(
        x.iter().flat_map(|r| r.iter().map(|&v| v as f32)).collect(),
        y.iter().map(|&v| v as f32).collect(),
        theta,
        x.iter().flat_map(|r| r.iter().map(|&v| v as f32)).collect(),
    )?;
    let nat = native.posterior(&x);
    let max_err = aot
        .mean
        .iter()
        .zip(nat.mean.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("selftest: max |aot - native| posterior mean error = {max_err:.2e}");
    if max_err > 1e-2 {
        bail!("artifact/native mismatch");
    }
    println!("selftest OK (three-layer stack is numerically consistent)");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "quickstart" => cmd_quickstart(&args),
        "sw-opt" => cmd_sw_opt(&args),
        "codesign" => cmd_codesign(&args),
        "schedule" => cmd_schedule(&args),
        "transfer" => cmd_transfer(&args),
        "trace" => cmd_trace(&args),
        "selftest" => cmd_selftest(&args),
        "fig3" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let p = fig3::run(&opts, &fig3::FIG3_LAYERS, "fig3.csv")?;
            println!("wrote {}", p.display());
            Ok(())
        }
        "fig16" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let names = fig3::all_layer_names();
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            let p = fig3::run(&opts, &refs, "fig16.csv")?;
            println!("wrote {}", p.display());
            Ok(())
        }
        "fig4" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let models = args.str("model", "resnet,dqn,mlp,transformer");
            let models: Vec<&str> = models.split(',').collect();
            let p = fig4::run(&opts, &models, "fig4.csv")?;
            println!("wrote {}", p.display());
            Ok(())
        }
        "fig5a" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let models = args.str("model", "resnet,dqn,mlp,transformer");
            let models: Vec<&str> = models.split(',').collect();
            let rows = fig5a::run(&opts, &models, "fig5a.csv")?;
            println!("model        ratio   improvement");
            for r in rows {
                println!("{:<12} {:.3}   {:.1}%", r.model, r.ratio, (1.0 - r.ratio) * 100.0);
            }
            Ok(())
        }
        "fig5b" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let layer = args.str("layer", "ResNet-K4");
            let p = fig5bc::run_surrogate_ablation(&opts, &layer, "fig5b.csv")?;
            println!("wrote {}", p.display());
            Ok(())
        }
        "fig5c" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let layer = args.str("layer", "ResNet-K4");
            let p = fig5bc::run_lambda_sweep(&opts, &layer, &fig5bc::LAMBDAS, "fig5c.csv")?;
            println!("wrote {}", p.display());
            Ok(())
        }
        "fig17" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            for layer in ["ResNet-K2", "DQN-K2", "MLP-K2", "Transformer-K2"] {
                fig5bc::run_surrogate_ablation(&opts, layer, &format!("fig17_{layer}.csv"))?;
            }
            println!("wrote results/fig17_*.csv");
            Ok(())
        }
        "fig18" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            for layer in ["ResNet-K2", "DQN-K2", "MLP-K2", "Transformer-K2"] {
                fig5bc::run_lambda_sweep(
                    &opts,
                    layer,
                    &fig5bc::LAMBDAS,
                    &format!("fig18_{layer}.csv"),
                )?;
            }
            println!("wrote results/fig18_*.csv");
            Ok(())
        }
        "report" => {
            let dir: std::path::PathBuf = args.str("out", "results").into();
            let md = codesign::figures::report::render(&dir)?;
            let path = dir.join("REPORT.md");
            std::fs::write(&path, &md)?;
            println!("{md}\n(written to {})", path.display());
            Ok(())
        }
        "specialize" => {
            // per-layer hardware specialization (paper SS5.1 footnote 1)
            let (b, _s) = backend(&args)?;
            let model_name = args.str("model", "dqn");
            let model = model_by_name(&model_name).context("unknown model")?;
            let ncfg = NestedConfig {
                hw_trials: args.get("hw-trials", 20usize)?,
                sw_trials: args.get("sw-trials", 100usize)?,
                ..NestedConfig::default()
            };
            let res = codesign::opt::per_layer::specialize(
                &model,
                &ncfg,
                sw_method(&args.str("method", "bo"))?,
                &b,
                args.get("seed", 2020u64)?,
            );
            println!("per-layer hardware specialization on {model_name}:");
            for (name, edp, trace) in &res.layers {
                if let Some(hw) = &trace.best_hw {
                    println!("  {name}: EDP {edp:.4e}");
                    println!("    {}", insight::describe_hw("hw", hw));
                }
            }
            println!("sum of per-layer optima: {:.4e}", res.total_edp);
            println!("(compare against the model-wide design from `codesign codesign`)");
            Ok(())
        }
        "insight" => {
            let (b, _s) = backend(&args)?;
            let opts = fig_opts(&args, b)?;
            let model = args.str("model", "dqn");
            let rep = insight::run(&opts, &model, None, "insight.csv")?;
            println!("{}", insight::describe_hw("hardware under test", &rep.hw));
            println!("{}", insight::describe_hw("Eyeriss reference ", &eyeriss_hw(168)));
            for (name, bo, heur, pct) in rep.rows {
                println!(
                    "{name}: BO {bo:.3e}  heuristic {heur:.3e}  (+{pct:.1}% worse; paper: ~52%)"
                );
            }
            Ok(())
        }
        _ => {
            println!(
                "usage: codesign <quickstart|sw-opt|codesign|schedule|transfer|trace|selftest|specialize|report|fig3|fig4|fig5a|fig5b|fig5c|fig16|fig17|fig18|insight> [flags]\n\
                 flags: --model M --layer L --method bo|random|round-bo|tvm-xgb|tvm-treegru \n\
                        --trials N --hw-trials N --sw-trials N --repeats N --scale F \n\
                        --seed N --threads N --out DIR --native \n\
                        --strategy nested|semi-decoupled (codesign/schedule: outer-loop \n\
                        strategy; semi-decoupled knobs: --table-cells N --cell-sw-trials N \n\
                        --topk N, gap reported in metrics/trace) \n\
                        --cache-policy slru|fifo --cache-snapshot PATH (codesign: persist \n\
                        the evaluation cache and warm-start follow-up runs from it) \n\
                        --models A,B,... --jobs N (schedule: run one co-design job per \n\
                        model concurrently, at most N at once, over one shared cache) \n\
                        --source-checkpoint PATH (transfer: warm-start the search from a \n\
                        prior run's best_design_*.txt incumbent) \n\
                        --trace PATH | --trace-dir DIR (write run-trace journals; add \n\
                        --trace-wall for wall-clock data) --metrics-addr HOST:PORT \n\
                        --metrics-out PATH (schedule: serve/dump the fleet exposition) \n\
                 trace: codesign trace summarize <j.jsonl> | trace diff <a.jsonl> <b.jsonl>"
            );
            Ok(())
        }
    }
}
