//! Run metrics: aggregate telemetry across the nested search (simulator
//! evaluations, rejection-sampling draws, feasibility rates, wall time).
//! Reported at the end of every CLI run and recorded in EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Debug)]
pub struct Metrics {
    pub sim_evals: AtomicU64,
    pub raw_draws: AtomicU64,
    pub feasible_evals: AtomicU64,
    pub gp_fits: AtomicU64,
    start: Instant,
}

impl Metrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Metrics {
            sim_evals: AtomicU64::new(0),
            raw_draws: AtomicU64::new(0),
            feasible_evals: AtomicU64::new(0),
            gp_fits: AtomicU64::new(0),
            start: Instant::now(),
        })
    }

    pub fn add_trace(&self, evals: &[f64], raw_draws: u64) {
        self.sim_evals.fetch_add(evals.len() as u64, Ordering::Relaxed);
        self.raw_draws.fetch_add(raw_draws, Ordering::Relaxed);
        self.feasible_evals.fetch_add(
            evals.iter().filter(|e| e.is_finite()).count() as u64,
            Ordering::Relaxed,
        );
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Fraction of raw design-space draws that were feasible (cf. the
    /// paper's ~22K draws per 150 feasible points observation).
    pub fn feasibility_rate(&self) -> f64 {
        let evals = self.sim_evals.load(Ordering::Relaxed) as f64;
        let draws = self.raw_draws.load(Ordering::Relaxed) as f64;
        if draws == 0.0 {
            return 0.0;
        }
        evals / draws
    }

    pub fn report(&self) -> String {
        format!(
            "sim_evals={} feasible={} raw_draws={} feasibility_rate={:.5} elapsed={:.1}s",
            self.sim_evals.load(Ordering::Relaxed),
            self.feasible_evals.load(Ordering::Relaxed),
            self.raw_draws.load(Ordering::Relaxed),
            self.feasibility_rate(),
            self.elapsed_secs()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_threads() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    m.add_trace(&[1.0, f64::INFINITY, 3.0], 100);
                });
            }
        });
        assert_eq!(m.sim_evals.load(Ordering::Relaxed), 12);
        assert_eq!(m.feasible_evals.load(Ordering::Relaxed), 8);
        assert_eq!(m.raw_draws.load(Ordering::Relaxed), 400);
        assert!(m.report().contains("sim_evals=12"));
    }
}
