//! Run metrics: aggregate telemetry across the nested search (simulator
//! evaluations, rejection-sampling draws, feasibility rates, wall time,
//! evaluation-cache hit/miss/eviction counts from `model::cache`).
//! Reported at the end of every CLI run and recorded in EXPERIMENTS.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::model::cache::CacheStats;
use crate::model::delta::telemetry::DeltaStats;
use crate::obs::clock::Stopwatch;
use crate::space::feasible::telemetry::FeasibilityStats;
use crate::surrogate::telemetry::SurrogateStats;

#[derive(Debug)]
pub struct Metrics {
    /// Evaluations *requested* by the searches (trace length). With the
    /// memoized engine this includes cache hits; the number of cost-model
    /// invocations that actually ran is `cache_misses`.
    pub sim_evals: AtomicU64,
    pub raw_draws: AtomicU64,
    pub feasible_evals: AtomicU64,
    /// Surrogate-numerics snapshot (stored per run via `record_surrogate`):
    /// full hyperparameter fits, full data-only refits, O(n^2) rank-1
    /// extends, extends that fell back to a refit, fits that failed at max
    /// jitter (degraded to the prior), and total jitter escalations.
    pub gp_fits: AtomicU64,
    pub gp_data_refits: AtomicU64,
    pub gp_extends: AtomicU64,
    pub gp_extend_fallbacks: AtomicU64,
    pub gp_fit_failures: AtomicU64,
    pub gp_jitter_escalations: AtomicU64,
    /// Scheduled GP refits that reused the previous theta as a shrunk local
    /// grid center, and the full-grid NLL evaluations that saved.
    pub gp_warm_refits: AtomicU64,
    pub gp_warm_grid_saved: AtomicU64,
    /// Feasibility-engine snapshot (stored per run via
    /// `record_feasibility`): candidates constructed valid-by-construction,
    /// feasibility-preserving perturbations (`fallbacks` counts only
    /// *degradations*, which stay at zero on healthy constructive spaces),
    /// nearest-feasible projections (and failures), samples / raw draws
    /// that went through the rejection fallback, and infeasible-space
    /// detections.
    pub feas_constructed: AtomicU64,
    pub feas_perturbations: AtomicU64,
    pub feas_perturbation_fallbacks: AtomicU64,
    pub feas_projections: AtomicU64,
    pub feas_projection_failures: AtomicU64,
    pub feas_fallback_samples: AtomicU64,
    pub feas_fallback_draws: AtomicU64,
    pub feas_infeasible_spaces: AtomicU64,
    /// Search-loop degradations: planned work skipped/truncated because no
    /// candidate could be sampled (consumer-side; zero on healthy runs).
    pub feas_degraded_skips: AtomicU64,
    /// Cross-space pruning snapshot (stored per run via
    /// `record_feasibility`): per-layer certificates computed, hardware
    /// points rejected before any simulator evaluation, lattice-derived
    /// round-BO boxes, and their accumulated box-volume shrink factor in
    /// thousandths (divide by `1000 * prune_lattice_boxes` for the mean).
    pub prune_certificates: AtomicU64,
    pub prune_rejections: AtomicU64,
    /// Certificate-store traffic: consultations served from the shared
    /// memo vs computed fresh (and then shared).
    pub prune_cert_hits: AtomicU64,
    pub prune_cert_misses: AtomicU64,
    pub prune_lattice_boxes: AtomicU64,
    pub prune_box_shrink_milli: AtomicU64,
    /// Semi-decoupled search snapshot (stored per run via
    /// `record_feasibility`): certified-nonempty lattice cells built into
    /// per-layer mapping tables (zero when a shared table was reused —
    /// the build amortized across jobs), outer-loop evaluations served as
    /// O(1) table lookups, and finalists re-searched exactly to bound the
    /// optimality gap.
    pub table_cells: AtomicU64,
    pub table_hits: AtomicU64,
    pub gap_resolved: AtomicU64,
    /// Delta-evaluation snapshot (stored per run via `record_delta`):
    /// evaluations served through the incremental terms cache, evaluations
    /// that fell back to a full analyze, and tile levels re-derived across
    /// all delta evals (0-3 each; lower means more reuse).
    pub delta_evals: AtomicU64,
    pub delta_fallbacks: AtomicU64,
    pub delta_levels_recomputed: AtomicU64,
    /// Evaluation-cache snapshot (stored, not accumulated: the cache keeps
    /// its own monotone counters).
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_evictions: AtomicU64,
    pub cache_entries: AtomicU64,
    /// Segmented-LRU occupancy and movement (0 under the FIFO policy).
    pub cache_probationary: AtomicU64,
    pub cache_protected: AtomicU64,
    pub cache_promotions: AtomicU64,
    pub cache_demotions: AtomicU64,
    /// Cross-process warm start: entries loaded from a snapshot, and hits
    /// those entries served.
    pub cache_snapshot_loaded: AtomicU64,
    pub cache_snapshot_hits: AtomicU64,
    /// Persistence failures in the search hot path (accumulated, not
    /// stored): incumbent checkpoints whose save failed, and cache-snapshot
    /// load/save operations that failed. The run degrades (incumbent stays
    /// in memory; cache stays cold/unsaved) but the failures no longer
    /// vanish into stderr.
    pub checkpoint_save_failures: AtomicU64,
    pub snapshot_io_failures: AtomicU64,
    /// Trace-journal create/write failures (accumulated): the run
    /// continues untraced but the degradation is visible in the report.
    pub trace_io_failures: AtomicU64,
    start: Stopwatch,
}

impl Metrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Metrics {
            sim_evals: AtomicU64::new(0),
            raw_draws: AtomicU64::new(0),
            feasible_evals: AtomicU64::new(0),
            gp_fits: AtomicU64::new(0),
            gp_data_refits: AtomicU64::new(0),
            gp_extends: AtomicU64::new(0),
            gp_extend_fallbacks: AtomicU64::new(0),
            gp_fit_failures: AtomicU64::new(0),
            gp_jitter_escalations: AtomicU64::new(0),
            gp_warm_refits: AtomicU64::new(0),
            gp_warm_grid_saved: AtomicU64::new(0),
            feas_constructed: AtomicU64::new(0),
            feas_perturbations: AtomicU64::new(0),
            feas_perturbation_fallbacks: AtomicU64::new(0),
            feas_projections: AtomicU64::new(0),
            feas_projection_failures: AtomicU64::new(0),
            feas_fallback_samples: AtomicU64::new(0),
            feas_fallback_draws: AtomicU64::new(0),
            feas_infeasible_spaces: AtomicU64::new(0),
            feas_degraded_skips: AtomicU64::new(0),
            prune_certificates: AtomicU64::new(0),
            prune_rejections: AtomicU64::new(0),
            prune_cert_hits: AtomicU64::new(0),
            prune_cert_misses: AtomicU64::new(0),
            prune_lattice_boxes: AtomicU64::new(0),
            prune_box_shrink_milli: AtomicU64::new(0),
            table_cells: AtomicU64::new(0),
            table_hits: AtomicU64::new(0),
            gap_resolved: AtomicU64::new(0),
            delta_evals: AtomicU64::new(0),
            delta_fallbacks: AtomicU64::new(0),
            delta_levels_recomputed: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            cache_entries: AtomicU64::new(0),
            cache_probationary: AtomicU64::new(0),
            cache_protected: AtomicU64::new(0),
            cache_promotions: AtomicU64::new(0),
            cache_demotions: AtomicU64::new(0),
            cache_snapshot_loaded: AtomicU64::new(0),
            cache_snapshot_hits: AtomicU64::new(0),
            checkpoint_save_failures: AtomicU64::new(0),
            snapshot_io_failures: AtomicU64::new(0),
            trace_io_failures: AtomicU64::new(0),
            start: Stopwatch::start(),
        })
    }

    /// Surface an evaluation-cache snapshot in the run telemetry.
    pub fn record_cache(&self, stats: CacheStats) {
        self.cache_hits.store(stats.hits, Ordering::Relaxed);
        self.cache_misses.store(stats.misses, Ordering::Relaxed);
        self.cache_evictions.store(stats.evictions, Ordering::Relaxed);
        self.cache_entries.store(stats.entries, Ordering::Relaxed);
        self.cache_probationary.store(stats.probationary, Ordering::Relaxed);
        self.cache_protected.store(stats.protected, Ordering::Relaxed);
        self.cache_promotions.store(stats.promotions, Ordering::Relaxed);
        self.cache_demotions.store(stats.demotions, Ordering::Relaxed);
        self.cache_snapshot_loaded.store(stats.snapshot_loaded, Ordering::Relaxed);
        self.cache_snapshot_hits.store(stats.snapshot_hits, Ordering::Relaxed);
    }

    /// Surface a surrogate-numerics snapshot (typically the per-run delta
    /// of the process-global counters) in the run telemetry.
    pub fn record_surrogate(&self, stats: SurrogateStats) {
        self.gp_fits.store(stats.fits, Ordering::Relaxed);
        self.gp_data_refits.store(stats.data_refits, Ordering::Relaxed);
        self.gp_extends.store(stats.extends, Ordering::Relaxed);
        self.gp_extend_fallbacks.store(stats.extend_fallbacks, Ordering::Relaxed);
        self.gp_fit_failures.store(stats.fit_failures, Ordering::Relaxed);
        self.gp_jitter_escalations.store(stats.jitter_escalations, Ordering::Relaxed);
        self.gp_warm_refits.store(stats.warm_refits, Ordering::Relaxed);
        self.gp_warm_grid_saved.store(stats.warm_grid_saved, Ordering::Relaxed);
    }

    /// Surface a feasibility-engine snapshot (typically the per-run delta
    /// of the process-global counters) in the run telemetry.
    pub fn record_feasibility(&self, stats: FeasibilityStats) {
        self.feas_constructed.store(stats.constructed, Ordering::Relaxed);
        self.feas_perturbations.store(stats.perturbations, Ordering::Relaxed);
        self.feas_perturbation_fallbacks.store(stats.perturbation_fallbacks, Ordering::Relaxed);
        self.feas_projections.store(stats.projections, Ordering::Relaxed);
        self.feas_projection_failures.store(stats.projection_failures, Ordering::Relaxed);
        self.feas_fallback_samples.store(stats.fallback_samples, Ordering::Relaxed);
        self.feas_fallback_draws.store(stats.fallback_draws, Ordering::Relaxed);
        self.feas_infeasible_spaces.store(stats.infeasible_spaces, Ordering::Relaxed);
        self.feas_degraded_skips.store(stats.degraded_skips, Ordering::Relaxed);
        self.prune_certificates.store(stats.prune_certificates, Ordering::Relaxed);
        self.prune_rejections.store(stats.prune_rejections, Ordering::Relaxed);
        self.prune_cert_hits.store(stats.cert_hits, Ordering::Relaxed);
        self.prune_cert_misses.store(stats.cert_misses, Ordering::Relaxed);
        self.prune_lattice_boxes.store(stats.lattice_boxes, Ordering::Relaxed);
        self.prune_box_shrink_milli.store(stats.lattice_box_shrink_milli, Ordering::Relaxed);
        self.table_cells.store(stats.table_cells, Ordering::Relaxed);
        self.table_hits.store(stats.table_hits, Ordering::Relaxed);
        self.gap_resolved.store(stats.gap_resolved, Ordering::Relaxed);
    }

    /// An incumbent-checkpoint save failed in the search hot path.
    pub fn record_checkpoint_save_failure(&self) {
        self.checkpoint_save_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// A cache-snapshot load or save failed; the run degrades to a cold
    /// start / unsaved cache.
    pub fn record_snapshot_io_failure(&self) {
        self.snapshot_io_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Trace-journal IO failures accumulated by the run's `RunTracer`
    /// (folded in once at run end; the journal degrades to disabled).
    pub fn add_trace_io_failures(&self, n: u64) {
        self.trace_io_failures.fetch_add(n, Ordering::Relaxed);
    }

    /// Surface a delta-evaluation snapshot (typically the per-run delta of
    /// the process-global counters) in the run telemetry.
    pub fn record_delta(&self, stats: DeltaStats) {
        self.delta_evals.store(stats.delta_evals, Ordering::Relaxed);
        self.delta_fallbacks.store(stats.delta_fallbacks, Ordering::Relaxed);
        self.delta_levels_recomputed.store(stats.levels_recomputed, Ordering::Relaxed);
    }

    /// Fraction of evaluation requests served from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.cache_hits.load(Ordering::Relaxed) as f64;
        let misses = self.cache_misses.load(Ordering::Relaxed) as f64;
        if hits + misses == 0.0 {
            return 0.0;
        }
        hits / (hits + misses)
    }

    pub fn add_trace(&self, evals: &[f64], raw_draws: u64) {
        self.sim_evals.fetch_add(evals.len() as u64, Ordering::Relaxed);
        self.raw_draws.fetch_add(raw_draws, Ordering::Relaxed);
        self.feasible_evals.fetch_add(
            evals.iter().filter(|e| e.is_finite()).count() as u64,
            Ordering::Relaxed,
        );
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Fraction of raw design-space draws that were feasible (cf. the
    /// paper's ~22K draws per 150 feasible points observation).
    pub fn feasibility_rate(&self) -> f64 {
        let evals = self.sim_evals.load(Ordering::Relaxed) as f64;
        let draws = self.raw_draws.load(Ordering::Relaxed) as f64;
        if draws == 0.0 {
            return 0.0;
        }
        evals / draws
    }

    pub fn report(&self) -> String {
        format!(
            "sim_evals={} feasible={} raw_draws={} feasibility_rate={:.5} \
             feas_constructed={} feas_perturbations={} feas_perturbation_fallbacks={} \
             feas_projections={} feas_projection_failures={} feas_fallback_samples={} \
             feas_fallback_draws={} feas_infeasible_spaces={} feas_degraded_skips={} \
             prune_certificates={} prune_rejections={} prune_cert_hits={} \
             prune_cert_misses={} prune_lattice_boxes={} \
             prune_box_shrink_milli={} \
             table_cells={} table_hits={} gap_resolved={} \
             gp_fits={} gp_data_refits={} gp_extends={} gp_extend_fallbacks={} \
             gp_fit_failures={} gp_jitter_escalations={} gp_warm_refits={} \
             gp_warm_grid_saved={} \
             delta_evals={} delta_fallbacks={} delta_levels_recomputed={} \
             cache_hits={} cache_misses={} cache_hit_rate={:.3} cache_evictions={} \
             cache_entries={} cache_probationary={} cache_protected={} \
             cache_promotions={} cache_demotions={} cache_snapshot_loaded={} \
             cache_snapshot_hits={} checkpoint_save_failures={} \
             snapshot_io_failures={} trace_io_failures={} elapsed={:.1}s",
            self.sim_evals.load(Ordering::Relaxed),
            self.feasible_evals.load(Ordering::Relaxed),
            self.raw_draws.load(Ordering::Relaxed),
            self.feasibility_rate(),
            self.feas_constructed.load(Ordering::Relaxed),
            self.feas_perturbations.load(Ordering::Relaxed),
            self.feas_perturbation_fallbacks.load(Ordering::Relaxed),
            self.feas_projections.load(Ordering::Relaxed),
            self.feas_projection_failures.load(Ordering::Relaxed),
            self.feas_fallback_samples.load(Ordering::Relaxed),
            self.feas_fallback_draws.load(Ordering::Relaxed),
            self.feas_infeasible_spaces.load(Ordering::Relaxed),
            self.feas_degraded_skips.load(Ordering::Relaxed),
            self.prune_certificates.load(Ordering::Relaxed),
            self.prune_rejections.load(Ordering::Relaxed),
            self.prune_cert_hits.load(Ordering::Relaxed),
            self.prune_cert_misses.load(Ordering::Relaxed),
            self.prune_lattice_boxes.load(Ordering::Relaxed),
            self.prune_box_shrink_milli.load(Ordering::Relaxed),
            self.table_cells.load(Ordering::Relaxed),
            self.table_hits.load(Ordering::Relaxed),
            self.gap_resolved.load(Ordering::Relaxed),
            self.gp_fits.load(Ordering::Relaxed),
            self.gp_data_refits.load(Ordering::Relaxed),
            self.gp_extends.load(Ordering::Relaxed),
            self.gp_extend_fallbacks.load(Ordering::Relaxed),
            self.gp_fit_failures.load(Ordering::Relaxed),
            self.gp_jitter_escalations.load(Ordering::Relaxed),
            self.gp_warm_refits.load(Ordering::Relaxed),
            self.gp_warm_grid_saved.load(Ordering::Relaxed),
            self.delta_evals.load(Ordering::Relaxed),
            self.delta_fallbacks.load(Ordering::Relaxed),
            self.delta_levels_recomputed.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.cache_misses.load(Ordering::Relaxed),
            self.cache_hit_rate(),
            self.cache_evictions.load(Ordering::Relaxed),
            self.cache_entries.load(Ordering::Relaxed),
            self.cache_probationary.load(Ordering::Relaxed),
            self.cache_protected.load(Ordering::Relaxed),
            self.cache_promotions.load(Ordering::Relaxed),
            self.cache_demotions.load(Ordering::Relaxed),
            self.cache_snapshot_loaded.load(Ordering::Relaxed),
            self.cache_snapshot_hits.load(Ordering::Relaxed),
            self.checkpoint_save_failures.load(Ordering::Relaxed),
            self.snapshot_io_failures.load(Ordering::Relaxed),
            self.trace_io_failures.load(Ordering::Relaxed),
            self.elapsed_secs()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_threads() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    m.add_trace(&[1.0, f64::INFINITY, 3.0], 100);
                });
            }
        });
        assert_eq!(m.sim_evals.load(Ordering::Relaxed), 12);
        assert_eq!(m.feasible_evals.load(Ordering::Relaxed), 8);
        assert_eq!(m.raw_draws.load(Ordering::Relaxed), 400);
        assert!(m.report().contains("sim_evals=12"));
    }

    #[test]
    fn cache_snapshot_is_stored_not_accumulated() {
        let m = Metrics::new();
        m.record_cache(CacheStats {
            hits: 10,
            misses: 30,
            evictions: 2,
            entries: 25,
            ..CacheStats::default()
        });
        m.record_cache(CacheStats {
            hits: 30,
            misses: 30,
            evictions: 2,
            entries: 25,
            probationary: 20,
            protected: 5,
            promotions: 7,
            demotions: 1,
            snapshot_loaded: 12,
            snapshot_hits: 9,
        });
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 30);
        assert!((m.cache_hit_rate() - 0.5).abs() < 1e-12);
        let report = m.report();
        assert!(report.contains("cache_hits=30"));
        assert!(report.contains("cache_hit_rate=0.500"));
        assert!(report.contains("cache_probationary=20"));
        assert!(report.contains("cache_protected=5"));
        assert!(report.contains("cache_promotions=7"));
        assert!(report.contains("cache_snapshot_loaded=12"));
        assert!(report.contains("cache_snapshot_hits=9"));
    }

    #[test]
    fn surrogate_snapshot_is_reported() {
        let m = Metrics::new();
        m.record_surrogate(SurrogateStats {
            fits: 4,
            data_refits: 2,
            extends: 40,
            extend_fallbacks: 1,
            fit_failures: 3,
            jitter_escalations: 7,
            warm_refits: 3,
            warm_grid_saved: 36,
        });
        let report = m.report();
        assert!(report.contains("gp_fits=4"));
        assert!(report.contains("gp_data_refits=2"));
        assert!(report.contains("gp_extends=40"));
        assert!(report.contains("gp_extend_fallbacks=1"));
        assert!(report.contains("gp_fit_failures=3"));
        assert!(report.contains("gp_jitter_escalations=7"));
        assert!(report.contains("gp_warm_refits=3"));
        assert!(report.contains("gp_warm_grid_saved=36"));
    }

    #[test]
    fn feasibility_snapshot_is_reported() {
        let m = Metrics::new();
        m.record_feasibility(FeasibilityStats {
            constructed: 1200,
            perturbations: 80,
            perturbation_fallbacks: 2,
            projections: 25,
            projection_failures: 1,
            fallback_samples: 3,
            fallback_draws: 9000,
            infeasible_spaces: 4,
            degraded_skips: 5,
            prune_certificates: 640,
            prune_rejections: 17,
            cert_hits: 410,
            cert_misses: 230,
            lattice_boxes: 6,
            lattice_box_shrink_milli: 9200,
            table_cells: 31,
            table_hits: 120,
            gap_resolved: 3,
        });
        let report = m.report();
        assert!(report.contains("feas_constructed=1200"));
        assert!(report.contains("feas_perturbations=80"));
        assert!(report.contains("feas_perturbation_fallbacks=2"));
        assert!(report.contains("feas_projections=25"));
        assert!(report.contains("feas_projection_failures=1"));
        assert!(report.contains("feas_fallback_samples=3"));
        assert!(report.contains("feas_fallback_draws=9000"));
        assert!(report.contains("feas_infeasible_spaces=4"));
        assert!(report.contains("feas_degraded_skips=5"));
        assert!(report.contains("prune_certificates=640"));
        assert!(report.contains("prune_rejections=17"));
        assert!(report.contains("prune_cert_hits=410"));
        assert!(report.contains("prune_cert_misses=230"));
        assert!(report.contains("prune_lattice_boxes=6"));
        assert!(report.contains("prune_box_shrink_milli=9200"));
        assert!(report.contains("table_cells=31"));
        assert!(report.contains("table_hits=120"));
        assert!(report.contains("gap_resolved=3"));
    }

    #[test]
    fn persistence_failures_accumulate_and_are_reported() {
        let m = Metrics::new();
        m.record_checkpoint_save_failure();
        m.record_checkpoint_save_failure();
        m.record_snapshot_io_failure();
        m.add_trace_io_failures(3);
        let report = m.report();
        assert!(report.contains("checkpoint_save_failures=2"), "{report}");
        assert!(report.contains("snapshot_io_failures=1"), "{report}");
        assert!(report.contains("trace_io_failures=3"), "{report}");
    }

    #[test]
    fn delta_snapshot_is_reported() {
        let m = Metrics::new();
        m.record_delta(DeltaStats {
            delta_evals: 500,
            delta_fallbacks: 12,
            levels_recomputed: 730,
        });
        let report = m.report();
        assert!(report.contains("delta_evals=500"));
        assert!(report.contains("delta_fallbacks=12"));
        assert!(report.contains("delta_levels_recomputed=730"));
    }

    /// Parse a `key=value` report line back into a map — the report is the
    /// serialization format downstream tooling (EXPERIMENTS.md, the CI
    /// warm-start grep) consumes, so it must stay token-splittable with
    /// exactly one `=` per token.
    fn parse_report(report: &str) -> std::collections::HashMap<String, String> {
        report
            .split_whitespace()
            .map(|tok| {
                let (k, v) = tok.split_once('=').unwrap_or_else(|| {
                    panic!("report token without '=': {tok:?}")
                });
                assert!(!k.is_empty() && !v.is_empty(), "malformed token {tok:?}");
                (k.to_string(), v.to_string())
            })
            .collect()
    }

    #[test]
    fn report_round_trips_every_field_through_the_kv_format() {
        let m = Metrics::new();
        m.add_trace(&[1.0, f64::INFINITY, 3.0], 7);
        m.record_cache(CacheStats {
            hits: 10,
            misses: 30,
            evictions: 2,
            entries: 25,
            probationary: 20,
            protected: 5,
            promotions: 7,
            demotions: 1,
            snapshot_loaded: 12,
            snapshot_hits: 9,
        });
        m.record_surrogate(SurrogateStats {
            fits: 4,
            data_refits: 2,
            extends: 40,
            extend_fallbacks: 1,
            fit_failures: 3,
            jitter_escalations: 7,
            warm_refits: 3,
            warm_grid_saved: 36,
        });
        m.record_feasibility(FeasibilityStats {
            constructed: 11,
            perturbations: 12,
            perturbation_fallbacks: 13,
            projections: 14,
            projection_failures: 15,
            fallback_samples: 16,
            fallback_draws: 17,
            infeasible_spaces: 18,
            degraded_skips: 19,
            prune_certificates: 20,
            prune_rejections: 21,
            cert_hits: 27,
            cert_misses: 28,
            lattice_boxes: 22,
            lattice_box_shrink_milli: 23,
            table_cells: 29,
            table_hits: 30,
            gap_resolved: 31,
        });
        m.record_delta(DeltaStats {
            delta_evals: 24,
            delta_fallbacks: 25,
            levels_recomputed: 26,
        });
        m.record_checkpoint_save_failure();
        m.record_snapshot_io_failure();
        m.add_trace_io_failures(2);
        let kv = parse_report(&m.report());
        // every stored numeric field must survive the round trip verbatim
        let expect = [
            ("sim_evals", "3"),
            ("feasible", "2"),
            ("raw_draws", "7"),
            ("feas_constructed", "11"),
            ("feas_perturbations", "12"),
            ("feas_perturbation_fallbacks", "13"),
            ("feas_projections", "14"),
            ("feas_projection_failures", "15"),
            ("feas_fallback_samples", "16"),
            ("feas_fallback_draws", "17"),
            ("feas_infeasible_spaces", "18"),
            ("feas_degraded_skips", "19"),
            ("prune_certificates", "20"),
            ("prune_rejections", "21"),
            ("prune_cert_hits", "27"),
            ("prune_cert_misses", "28"),
            ("prune_lattice_boxes", "22"),
            ("prune_box_shrink_milli", "23"),
            ("table_cells", "29"),
            ("table_hits", "30"),
            ("gap_resolved", "31"),
            ("gp_fits", "4"),
            ("gp_data_refits", "2"),
            ("gp_extends", "40"),
            ("gp_extend_fallbacks", "1"),
            ("gp_fit_failures", "3"),
            ("gp_jitter_escalations", "7"),
            ("gp_warm_refits", "3"),
            ("gp_warm_grid_saved", "36"),
            ("delta_evals", "24"),
            ("delta_fallbacks", "25"),
            ("delta_levels_recomputed", "26"),
            ("cache_hits", "10"),
            ("cache_misses", "30"),
            ("cache_evictions", "2"),
            ("cache_entries", "25"),
            ("cache_probationary", "20"),
            ("cache_protected", "5"),
            ("cache_promotions", "7"),
            ("cache_demotions", "1"),
            ("cache_snapshot_loaded", "12"),
            ("cache_snapshot_hits", "9"),
            ("checkpoint_save_failures", "1"),
            ("snapshot_io_failures", "1"),
            ("trace_io_failures", "2"),
        ];
        for (k, v) in expect {
            assert_eq!(kv.get(k).map(String::as_str), Some(v), "field {k}");
        }
        // derived fields are present and parse as f64
        for k in ["feasibility_rate", "cache_hit_rate"] {
            let v = kv.get(k).unwrap_or_else(|| panic!("missing {k}"));
            assert!(v.parse::<f64>().is_ok(), "{k}={v} not a number");
        }
        assert!(kv.contains_key("elapsed"));
    }
}
