//! Checkpointing: the nested search persists its incumbent design (hardware
//! config + per-layer mappings + EDPs) as a human-readable key=value text
//! file after every hardware trial, so long co-design runs survive
//! interruption and the winning design can be inspected/reloaded (no serde
//! in the offline crate set — the format is a flat dotted-key list).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::arch::{DataflowOpt, HwConfig};
use crate::model::mapping::{Mapping, Split};
use crate::model::workload::{Dim, DIMS};

/// The persisted state of a co-design run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub trial: usize,
    pub best_edp: f64,
    /// Path of the evaluation-cache snapshot the run persists alongside the
    /// incumbent design (see `model::cache::EvalCache::save_snapshot`), so a
    /// resumed or follow-up run can warm-start from it. Optional: absent in
    /// checkpoints from runs without `--cache-snapshot`.
    pub cache_snapshot: Option<String>,
    pub hw: HwConfig,
    /// (layer name, mapping, layer EDP)
    pub layers: Vec<(String, Mapping, f64)>,
}

fn dataflow_str(d: DataflowOpt) -> &'static str {
    match d {
        DataflowOpt::FullAtPe => "full",
        DataflowOpt::Streamed => "streamed",
    }
}

fn parse_dataflow(s: &str) -> Result<DataflowOpt> {
    match s {
        "full" => Ok(DataflowOpt::FullAtPe),
        "streamed" => Ok(DataflowOpt::Streamed),
        other => bail!("bad dataflow {other}"),
    }
}

fn order_str(o: &[Dim; 6]) -> String {
    o.iter().map(|d| d.name()).collect()
}

fn parse_order(s: &str) -> Result<[Dim; 6]> {
    let mut out = DIMS;
    if s.len() != 6 {
        bail!("order must have 6 dims: {s}");
    }
    for (i, ch) in s.chars().enumerate() {
        out[i] = match ch {
            'R' => Dim::R,
            'S' => Dim::S,
            'P' => Dim::P,
            'Q' => Dim::Q,
            'C' => Dim::C,
            'K' => Dim::K,
            other => bail!("bad dim {other}"),
        };
    }
    Ok(out)
}

impl Checkpoint {
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("model={}\n", self.model));
        s.push_str(&format!("trial={}\n", self.trial));
        s.push_str(&format!("best_edp={:e}\n", self.best_edp));
        if let Some(snap) = &self.cache_snapshot {
            s.push_str(&format!("cache_snapshot={snap}\n"));
        }
        let h = &self.hw;
        s.push_str(&format!(
            "hw.pe_mesh={}x{}\nhw.lb={},{},{}\nhw.gb_mesh={}x{}\nhw.gb_geom={},{}\nhw.df={},{}\n",
            h.pe_mesh_x,
            h.pe_mesh_y,
            h.lb_inputs,
            h.lb_weights,
            h.lb_outputs,
            h.gb_mesh_x,
            h.gb_mesh_y,
            h.gb_block,
            h.gb_cluster,
            dataflow_str(h.df_filter_w),
            dataflow_str(h.df_filter_h),
        ));
        for (i, (name, m, edp)) in self.layers.iter().enumerate() {
            s.push_str(&format!("layer.{i}.name={name}\n"));
            s.push_str(&format!("layer.{i}.edp={edp:e}\n"));
            for d in DIMS {
                let sp = m.split(d);
                s.push_str(&format!(
                    "layer.{i}.split.{}={},{},{},{},{}\n",
                    d.name(),
                    sp.dram,
                    sp.glb,
                    sp.spatial_x,
                    sp.spatial_y,
                    sp.local
                ));
            }
            s.push_str(&format!(
                "layer.{i}.orders={},{},{}\n",
                order_str(&m.order_dram),
                order_str(&m.order_glb),
                order_str(&m.order_local)
            ));
        }
        s
    }

    pub fn from_text(text: &str) -> Result<Self> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').with_context(|| format!("bad line {line}"))?;
            kv.insert(k.to_string(), v.to_string());
        }
        let get = |k: &str| kv.get(k).cloned().ok_or_else(|| anyhow!("missing key {k}"));

        let mesh = get("hw.pe_mesh")?;
        let (mx, my) = mesh.split_once('x').ok_or_else(|| anyhow!("bad mesh"))?;
        let lb: Vec<u64> =
            get("hw.lb")?.split(',').map(|s| s.parse()).collect::<Result<_, _>>()?;
        let gbm = get("hw.gb_mesh")?;
        let (gx, gy) = gbm.split_once('x').ok_or_else(|| anyhow!("bad gb mesh"))?;
        let geom: Vec<u64> =
            get("hw.gb_geom")?.split(',').map(|s| s.parse()).collect::<Result<_, _>>()?;
        let df = get("hw.df")?;
        let (dw, dh) = df.split_once(',').ok_or_else(|| anyhow!("bad df"))?;
        let gb_mesh_x: u64 = gx.parse()?;
        let gb_mesh_y: u64 = gy.parse()?;
        let hw = HwConfig {
            pe_mesh_x: mx.parse()?,
            pe_mesh_y: my.parse()?,
            lb_inputs: lb[0],
            lb_weights: lb[1],
            lb_outputs: lb[2],
            gb_instances: gb_mesh_x * gb_mesh_y,
            gb_mesh_x,
            gb_mesh_y,
            gb_block: geom[0],
            gb_cluster: geom[1],
            df_filter_w: parse_dataflow(dw)?,
            df_filter_h: parse_dataflow(dh)?,
        };

        let mut layers = Vec::new();
        let mut i = 0;
        while let Ok(name) = get(&format!("layer.{i}.name")) {
            let edp: f64 = get(&format!("layer.{i}.edp"))?.parse()?;
            let mut splits = [Split::unit(); 6];
            for d in DIMS {
                let raw = get(&format!("layer.{i}.split.{}", d.name()))?;
                let v: Vec<u64> =
                    raw.split(',').map(|s| s.parse()).collect::<Result<_, _>>()?;
                splits[d.index()] = Split {
                    dram: v[0],
                    glb: v[1],
                    spatial_x: v[2],
                    spatial_y: v[3],
                    local: v[4],
                };
            }
            let orders = get(&format!("layer.{i}.orders"))?;
            let parts: Vec<&str> = orders.split(',').collect();
            let m = Mapping {
                splits,
                order_dram: parse_order(parts[0])?,
                order_glb: parse_order(parts[1])?,
                order_local: parse_order(parts[2])?,
            };
            layers.push((name, m, edp));
            i += 1;
        }

        Ok(Checkpoint {
            model: get("model")?,
            trial: get("trial")?.parse()?,
            best_edp: get("best_edp")?.parse()?,
            cache_snapshot: kv.get("cache_snapshot").cloned(),
            hw,
            layers,
        })
    }

    /// Persist atomically (temp file + rename): a crash mid-write leaves
    /// either the previous checkpoint or the new one, never a truncated
    /// unparseable file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        crate::util::fsio::atomic_write(path.as_ref(), &self.to_text())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_text(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::eyeriss::eyeriss_hw;
    use crate::workloads::specs::layer_by_name;

    #[test]
    fn text_roundtrip_exact() {
        let layer = layer_by_name("DQN-K2").unwrap();
        let m = Mapping::trivial(&layer);
        let ck = Checkpoint {
            model: "dqn".into(),
            trial: 17,
            best_edp: 3.25e-7,
            cache_snapshot: Some("results/cache_dqn.snap".into()),
            hw: eyeriss_hw(168),
            layers: vec![("DQN-K2".into(), m, 3.25e-7)],
        };
        let back = Checkpoint::from_text(&ck.to_text()).unwrap();
        assert_eq!(ck, back);

        // the snapshot pointer is optional: absent stays absent
        let mut bare = ck.clone();
        bare.cache_snapshot = None;
        let back = Checkpoint::from_text(&bare.to_text()).unwrap();
        assert_eq!(bare, back);
    }

    #[test]
    fn file_roundtrip() {
        let layer = layer_by_name("DQN-K1").unwrap();
        let ck = Checkpoint {
            model: "dqn".into(),
            trial: 0,
            best_edp: 1.0,
            cache_snapshot: None,
            hw: eyeriss_hw(168),
            layers: vec![("DQN-K1".into(), Mapping::trivial(&layer), 1.0)],
        };
        let dir = std::env::temp_dir().join("codesign_ck_test");
        let path = dir.join("ck.txt");
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_and_overwrites_cleanly() {
        let layer = layer_by_name("DQN-K1").unwrap();
        let mk = |trial| Checkpoint {
            model: "dqn".into(),
            trial,
            best_edp: 1.0 / (trial as f64 + 1.0),
            cache_snapshot: None,
            hw: eyeriss_hw(168),
            layers: vec![("DQN-K1".into(), Mapping::trivial(&layer), 1.0)],
        };
        let dir = std::env::temp_dir().join("codesign_ck_atomic_test");
        let path = dir.join("ck.txt");
        // repeated saves (the per-trial cadence of a real run) always leave
        // a complete, parseable file and no temp siblings
        for trial in 0..5 {
            mk(trial).save(&path).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(back.trial, trial);
        }
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(stray.is_empty(), "temp files left behind: {stray:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::from_text("not a checkpoint").is_err());
        assert!(Checkpoint::from_text("model=x\ntrial=zzz").is_err());
    }
}
