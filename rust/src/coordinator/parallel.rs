//! Scoped-thread parallel map (the offline crate set has no tokio/rayon).
//! Used by the co-design driver to run per-layer software searches
//! concurrently, and by the figure harnesses for repeats.

/// Apply `f` to each item on its own thread (bounded by `max_threads`) and
/// collect results in input order.
pub fn parallel_map<T, R, F>(items: &[T], max_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n);

    if threads == 1 {
        return items.iter().enumerate().map(|(i, item)| f(i, item)).collect();
    }

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                **crate::util::sync::lock_unpoisoned(&slots[i]) = Some(r);
            });
        }
    });

    // lint: allow(panic-freedom) — every index < n is claimed exactly once by the slot counter
    out.into_iter().map(|r| r.expect("worker must fill every slot")).collect()
}

/// Default worker count: physical parallelism capped at 8 (the searches are
/// memory-light; beyond the core count there is nothing to gain).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_coverage() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = [1, 2, 3];
        let out = parallel_map(&items, 1, |i, &x| i as i32 + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: [u8; 0] = [];
        let out: Vec<u8> = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn actually_runs_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let items: Vec<u32> = (0..16).collect();
        parallel_map(&items, 4, |_, _| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2, "no observed concurrency");
    }
}
