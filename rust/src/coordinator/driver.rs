//! The nested co-design driver (§4.1, Fig. 1): the outer hardware BO
//! proposes configurations; for each one, per-layer software mapping
//! searches run in parallel worker threads; layerwise EDPs are summed and
//! fed back; the incumbent design is checkpointed after every hardware
//! trial. This is the leader process of the system — the CLI's `codesign`
//! subcommand is a thin wrapper over `Driver::run`.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::parallel::{default_threads, parallel_map};
use crate::model::arch::HwConfig;
use crate::model::eval::Evaluator;
use crate::opt::config::NestedConfig;
use crate::opt::hw_search::{self, HwMethod, HwTrace};
use crate::opt::sw_search::{self, SwMethod, SwProblem};
use crate::space::hw_space::HwSpace;
use crate::space::sw_space::SwSpace;
use crate::surrogate::gp::GpBackend;
use crate::util::rng::Rng;
use crate::workloads::eyeriss::eyeriss_resources;
use crate::workloads::specs::ModelSpec;

/// Result of a co-design run.
pub struct CodesignOutcome {
    pub hw_trace: HwTrace,
    /// Best full design (hardware + per-layer mappings), if any trial was
    /// feasible.
    pub best: Option<Checkpoint>,
    pub metrics: Arc<Metrics>,
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct Driver {
    pub ncfg: NestedConfig,
    pub hw_method: HwMethod,
    pub sw_method: SwMethod,
    pub threads: usize,
    pub checkpoint_path: Option<PathBuf>,
    pub verbose: bool,
}

impl Driver {
    pub fn new(ncfg: NestedConfig) -> Self {
        Driver {
            ncfg,
            hw_method: HwMethod::Bo,
            sw_method: SwMethod::Bo { surrogate: sw_search::SurrogateKind::Gp },
            threads: default_threads(),
            checkpoint_path: None,
            verbose: true,
        }
    }

    /// Evaluate one hardware configuration: parallel per-layer software
    /// searches; returns the summed EDP and per-layer (mapping, EDP), or
    /// None if any layer has no findable mapping (the unknown constraint).
    pub fn evaluate_hardware(
        &self,
        model: &ModelSpec,
        hw: &HwConfig,
        backend: &GpBackend,
        metrics: &Metrics,
        seed: u64,
    ) -> Option<(f64, Vec<(String, crate::model::mapping::Mapping, f64)>)> {
        let resources = eyeriss_resources(model.num_pes);
        let eval = Evaluator::new(resources.clone());
        let backends: Vec<GpBackend> =
            (0..model.layers.len()).map(|_| backend.clone()).collect();
        let items: Vec<(usize, &crate::model::workload::Layer)> =
            model.layers.iter().enumerate().collect();

        let results = parallel_map(&items, self.threads, |_, &(li, layer)| {
            let problem = SwProblem {
                space: SwSpace::new(layer.clone(), hw.clone(), resources.clone()),
                eval: eval.clone(),
            };
            let mut rng = Rng::seed_from_u64(seed ^ (0x9E37 * (li as u64 + 1)));
            let trace = sw_search::search(
                self.sw_method,
                &problem,
                self.ncfg.sw_trials,
                &self.ncfg.sw_bo,
                &backends[li],
                &mut rng,
            );
            metrics.add_trace(&trace.evals, trace.raw_draws);
            trace
        });

        let mut total = 0.0;
        let mut layers = Vec::new();
        for (trace, layer) in results.iter().zip(model.layers.iter()) {
            let m = trace.best_mapping.clone()?; // None => unknown constraint
            total += trace.best_edp;
            layers.push((layer.name.clone(), m, trace.best_edp));
        }
        Some((total, layers))
    }

    /// Full nested co-design on a model.
    pub fn run(&self, model: &ModelSpec, backend: &GpBackend, seed: u64) -> CodesignOutcome {
        let metrics = Metrics::new();
        let space = HwSpace::new(eyeriss_resources(model.num_pes));
        let best: Mutex<Option<Checkpoint>> = Mutex::new(None);
        let mut trial = 0usize;

        let hw_trace = {
            let metrics_ref = Arc::clone(&metrics);
            let inner = |hw: &HwConfig| -> Option<f64> {
                let t = trial;
                trial += 1;
                let out = self.evaluate_hardware(model, hw, backend, &metrics_ref, seed + t as u64);
                if let Some((edp, layers)) = &out {
                    let mut guard = best.lock().unwrap();
                    let improved = guard.as_ref().map_or(true, |b| *edp < b.best_edp);
                    if improved {
                        let ck = Checkpoint {
                            model: model.name.to_string(),
                            trial: t,
                            best_edp: *edp,
                            hw: hw.clone(),
                            layers: layers.clone(),
                        };
                        if let Some(path) = &self.checkpoint_path {
                            if let Err(e) = ck.save(path) {
                                eprintln!("checkpoint save failed: {e:#}");
                            }
                        }
                        *guard = Some(ck);
                    }
                    if self.verbose {
                        eprintln!(
                            "[{}] hw trial {t}: edp {:.3e} (best {:.3e})",
                            model.name,
                            edp,
                            best.lock().unwrap().as_ref().map(|b| b.best_edp).unwrap_or(*edp)
                        );
                    }
                } else if self.verbose {
                    eprintln!("[{}] hw trial {t}: infeasible (no mapping found)", model.name);
                }
                out.map(|(edp, _)| edp)
            };

            let mut rng = Rng::seed_from_u64(seed);
            hw_search::search(
                self.hw_method,
                &space,
                inner,
                self.ncfg.hw_trials,
                &self.ncfg.hw_bo,
                backend,
                &mut rng,
            )
        };

        CodesignOutcome { hw_trace, best: best.into_inner().unwrap(), metrics }
    }
}

/// Evaluate the Eyeriss baseline itself: best mappings for each layer on the
/// fixed Eyeriss hardware (the denominator of Fig. 5a).
pub fn eyeriss_baseline(
    model: &ModelSpec,
    sw_method: SwMethod,
    sw_trials: usize,
    backend: &GpBackend,
    threads: usize,
    seed: u64,
) -> Option<(f64, Vec<(String, crate::model::mapping::Mapping, f64)>)> {
    let driver = Driver {
        ncfg: NestedConfig {
            sw_trials,
            ..NestedConfig::default()
        },
        hw_method: HwMethod::Bo,
        sw_method,
        threads,
        checkpoint_path: None,
        verbose: false,
    };
    let metrics = Metrics::new();
    let hw = crate::workloads::eyeriss::eyeriss_hw(model.num_pes);
    driver.evaluate_hardware(model, &hw, backend, &metrics, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::config::BoConfig;
    use crate::workloads::specs::dqn;

    fn tiny_cfg() -> NestedConfig {
        NestedConfig {
            hw_trials: 4,
            sw_trials: 12,
            hw_bo: BoConfig { warmup: 2, pool: 10, ..BoConfig::hardware() },
            sw_bo: BoConfig { warmup: 4, pool: 10, ..BoConfig::software() },
        }
    }

    #[test]
    fn nested_codesign_produces_a_design_native_backend() {
        let mut driver = Driver::new(tiny_cfg());
        driver.verbose = false;
        driver.threads = 2;
        let out = driver.run(&dqn(), &GpBackend::Native, 1);
        assert_eq!(out.hw_trace.evals.len(), 4);
        let best = out.best.expect("at least one feasible hardware trial");
        assert_eq!(best.layers.len(), 2);
        assert!(best.best_edp.is_finite());
        // the checkpointed EDP is the sum of layer EDPs
        let sum: f64 = best.layers.iter().map(|(_, _, e)| e).sum();
        assert!((sum - best.best_edp).abs() < 1e-9 * best.best_edp);
    }

    #[test]
    fn eyeriss_baseline_is_feasible() {
        let out = eyeriss_baseline(
            &dqn(),
            SwMethod::Random,
            10,
            &GpBackend::Native,
            2,
            3,
        );
        let (edp, layers) = out.expect("eyeriss must be mappable");
        assert!(edp.is_finite());
        assert_eq!(layers.len(), 2);
    }

    #[test]
    fn checkpoint_written_when_path_set() {
        let dir = std::env::temp_dir().join("codesign_driver_test");
        let path = dir.join("best.txt");
        let mut driver = Driver::new(tiny_cfg());
        driver.verbose = false;
        driver.threads = 2;
        driver.checkpoint_path = Some(path.clone());
        let out = driver.run(&dqn(), &GpBackend::Native, 2);
        if out.best.is_some() {
            let ck = Checkpoint::load(&path).unwrap();
            assert_eq!(ck.model, "dqn");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
