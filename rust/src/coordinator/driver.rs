//! The nested co-design driver (§4.1, Fig. 1): the outer hardware BO
//! proposes configuration *batches*; each batch fans out over the worker
//! pool as a (config x layer) cross product of per-layer software mapping
//! searches; layerwise EDPs are summed and fed back; the incumbent design
//! is checkpointed after every hardware trial. One evaluation cache is
//! shared across the entire run — every software search of every layer on
//! every hardware trial memoizes into it, so recurring design points
//! (warmup resamples, acquisition re-picks, per-layer overlap) are computed
//! once.
//!
//! As of the job-scheduling refactor the driver is a thin convenience
//! facade: [`Driver::run`] builds a [`JobSpec`] from its fields, schedules
//! it as one job on an ephemeral `runtime::jobs::JobScheduler` sharing the
//! driver's cache, and waits. All run state — pruned space, trial
//! accounting, incumbent/checkpoint logic, snapshot I/O, run-scoped
//! telemetry — lives in [`crate::coordinator::run::SearchRun`]; concurrent
//! multi-job use goes through the scheduler directly (the CLI's `schedule`
//! subcommand). Fixed-seed traces are bit-identical to the pre-refactor
//! driver: scheduling one job executes exactly the former `Driver::run`
//! body.

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::parallel::default_threads;
use crate::coordinator::run::{self, JobSpec, SearchStrategy};
use crate::model::arch::HwConfig;
use crate::model::cache::EvalCache;
use crate::model::mapping::Mapping;
use crate::obs::span::SpanStats;
use crate::obs::trace::TraceConfig;
use crate::opt::config::NestedConfig;
use crate::opt::hw_search::{HwMethod, HwTrace};
use crate::opt::sw_search::{self, SwMethod};
use crate::runtime::jobs::JobScheduler;
use crate::space::prune::CertificateStore;
use crate::surrogate::gp::GpBackend;
use crate::workloads::specs::ModelSpec;

/// Per-layer outcome of one hardware evaluation: (layer name, mapping, EDP).
pub type LayerOutcome = Vec<(String, Mapping, f64)>;

/// Result of a co-design run.
pub struct CodesignOutcome {
    pub hw_trace: HwTrace,
    /// Best full design (hardware + per-layer mappings), if any trial was
    /// feasible.
    pub best: Option<Checkpoint>,
    pub metrics: Arc<Metrics>,
    /// The run was cancelled before completing its configured trials; the
    /// trace, incumbent and metrics cover the work done up to that point.
    pub cancelled: bool,
    /// Per-phase span snapshot (counts, durations, latency histograms)
    /// accumulated by the run's profiler; see `obs::span`.
    pub spans: SpanStats,
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct Driver {
    pub ncfg: NestedConfig,
    pub hw_method: HwMethod,
    pub sw_method: SwMethod,
    /// Outer-loop strategy (nested / semi-decoupled / transfer); see
    /// [`SearchStrategy`]. `Nested` reproduces the classic driver.
    pub strategy: SearchStrategy,
    pub threads: usize,
    pub checkpoint_path: Option<PathBuf>,
    /// Cross-process cache persistence: when set, the run warm-starts by
    /// loading this snapshot (if present and fingerprint-compatible) and
    /// saves the cache back to it when the search finishes. Checkpoints
    /// record the path so follow-up runs can find the warm cache.
    pub cache_snapshot_path: Option<PathBuf>,
    /// Trace journaling for the run (see `obs::trace`); `None` is quiet.
    pub trace: Option<TraceConfig>,
    pub verbose: bool,
    /// Evaluation cache shared by every software search this driver runs.
    pub cache: Arc<EvalCache>,
}

impl Driver {
    pub fn new(ncfg: NestedConfig) -> Self {
        Driver {
            ncfg,
            hw_method: HwMethod::Bo,
            sw_method: SwMethod::Bo { surrogate: sw_search::SurrogateKind::Gp },
            strategy: SearchStrategy::Nested,
            threads: default_threads(),
            checkpoint_path: None,
            cache_snapshot_path: None,
            trace: None,
            verbose: true,
            cache: Arc::new(EvalCache::default()),
        }
    }

    /// Evaluate a batch of hardware configurations: the (config x layer)
    /// cross product of software searches runs across the worker pool in
    /// one `parallel_map`, so a warmup batch of W configs on an L-layer
    /// model exposes W*L-way parallelism instead of L-way. Returns, per
    /// config in order, the summed EDP and per-layer best mappings, or
    /// None if any layer has no findable mapping (the unknown constraint).
    ///
    /// Seeding matches the sequential formulation: config `i` of the batch
    /// behaves as trial `seed_base + i`.
    pub fn evaluate_hardware_batch(
        &self,
        model: &ModelSpec,
        hws: &[HwConfig],
        backend: &GpBackend,
        metrics: &Metrics,
        seed_base: u64,
    ) -> Vec<Option<(f64, LayerOutcome)>> {
        let ctx = run::HwBatchCtx {
            model,
            sw_method: self.sw_method,
            sw_trials: self.ncfg.sw_trials,
            sw_bo: &self.ncfg.sw_bo,
            threads: self.threads,
            cache: &self.cache,
            scope: None,
        };
        run::evaluate_hardware_batch(&ctx, hws, backend, metrics, seed_base)
    }

    /// Evaluate one hardware configuration (single-element batch).
    pub fn evaluate_hardware(
        &self,
        model: &ModelSpec,
        hw: &HwConfig,
        backend: &GpBackend,
        metrics: &Metrics,
        seed: u64,
    ) -> Option<(f64, LayerOutcome)> {
        self.evaluate_hardware_batch(model, std::slice::from_ref(hw), backend, metrics, seed)
            .pop()
            .flatten()
    }

    /// Full nested co-design on a model: schedule one job on an ephemeral
    /// scheduler sharing this driver's evaluation cache, and wait for it.
    pub fn run(&self, model: &ModelSpec, backend: &GpBackend, seed: u64) -> CodesignOutcome {
        let spec = JobSpec {
            model: model.clone(),
            ncfg: self.ncfg,
            hw_method: self.hw_method,
            sw_method: self.sw_method,
            strategy: self.strategy.clone(),
            threads: self.threads,
            seed,
            checkpoint_path: self.checkpoint_path.clone(),
            cache_snapshot_path: self.cache_snapshot_path.clone(),
            trace: self.trace.clone(),
            verbose: self.verbose,
        };
        let scheduler = JobScheduler::with_shared(
            backend.clone(),
            Arc::clone(&self.cache),
            Arc::new(CertificateStore::default()),
            1,
        );
        scheduler.submit(spec).wait()
    }
}

/// Evaluate the Eyeriss baseline itself: best mappings for each layer on the
/// fixed Eyeriss hardware (the denominator of Fig. 5a).
pub fn eyeriss_baseline(
    model: &ModelSpec,
    sw_method: SwMethod,
    sw_trials: usize,
    backend: &GpBackend,
    threads: usize,
    seed: u64,
) -> Option<(f64, LayerOutcome)> {
    let driver = Driver {
        ncfg: NestedConfig {
            sw_trials,
            ..NestedConfig::default()
        },
        hw_method: HwMethod::Bo,
        sw_method,
        strategy: SearchStrategy::Nested,
        threads,
        checkpoint_path: None,
        cache_snapshot_path: None,
        trace: None,
        verbose: false,
        cache: Arc::new(EvalCache::default()),
    };
    let metrics = Metrics::new();
    let hw = crate::workloads::eyeriss::eyeriss_hw(model.num_pes);
    driver.evaluate_hardware(model, &hw, backend, &metrics, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::config::BoConfig;
    use crate::workloads::specs::dqn;

    fn tiny_cfg() -> NestedConfig {
        NestedConfig {
            hw_trials: 4,
            sw_trials: 12,
            hw_bo: BoConfig { warmup: 2, pool: 10, ..BoConfig::hardware() },
            sw_bo: BoConfig { warmup: 4, pool: 10, ..BoConfig::software() },
        }
    }

    #[test]
    fn nested_codesign_produces_a_design_native_backend() {
        let mut driver = Driver::new(tiny_cfg());
        driver.verbose = false;
        driver.threads = 2;
        let out = driver.run(&dqn(), &GpBackend::Native, 1);
        assert_eq!(out.hw_trace.evals.len(), 4);
        assert!(!out.cancelled);
        let best = out.best.expect("at least one feasible hardware trial");
        assert_eq!(best.layers.len(), 2);
        assert!(best.best_edp.is_finite());
        // the checkpointed EDP is the sum of layer EDPs
        let sum: f64 = best.layers.iter().map(|(_, _, e)| e).sum();
        assert!((sum - best.best_edp).abs() < 1e-9 * best.best_edp);
    }

    #[test]
    fn eyeriss_baseline_is_feasible() {
        let out = eyeriss_baseline(
            &dqn(),
            SwMethod::Random,
            10,
            &GpBackend::Native,
            2,
            3,
        );
        let (edp, layers) = out.expect("eyeriss must be mappable");
        assert!(edp.is_finite());
        assert_eq!(layers.len(), 2);
    }

    #[test]
    fn checkpoint_written_when_path_set() {
        let dir = std::env::temp_dir().join("codesign_driver_test");
        let path = dir.join("best.txt");
        let mut driver = Driver::new(tiny_cfg());
        driver.verbose = false;
        driver.threads = 2;
        driver.checkpoint_path = Some(path.clone());
        let out = driver.run(&dqn(), &GpBackend::Native, 2);
        if out.best.is_some() {
            let ck = Checkpoint::load(&path).unwrap();
            assert_eq!(ck.model, "dqn");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_evaluation_matches_single_and_shares_cache() {
        let model = dqn();
        let driver = {
            let mut d = Driver::new(tiny_cfg());
            d.verbose = false;
            d.threads = 2;
            d.sw_method = SwMethod::Random;
            d
        };
        let hw = crate::workloads::eyeriss::eyeriss_hw(model.num_pes);
        let metrics = Metrics::new();
        // a batch of two identical configs with identical seeds must agree
        // with the single-config evaluation at the same seed
        let batch = driver.evaluate_hardware_batch(
            &model,
            &[hw.clone(), hw.clone()],
            &GpBackend::Native,
            &metrics,
            5,
        );
        let single = driver.evaluate_hardware(&model, &hw, &GpBackend::Native, &metrics, 5);
        assert_eq!(batch.len(), 2);
        let (batch_edp, _) = batch[0].as_ref().expect("eyeriss mappable");
        let (single_edp, _) = single.as_ref().expect("eyeriss mappable");
        assert_eq!(batch_edp.to_bits(), single_edp.to_bits());
        // the second, identical evaluation ran fully warm
        let stats = driver.cache.stats();
        assert!(stats.hits > 0, "identical configs must hit the shared cache: {stats:?}");
    }

    #[test]
    fn second_run_warm_starts_from_first_runs_snapshot() {
        let dir = std::env::temp_dir()
            .join(format!("codesign_snapshot_test_{}", std::process::id()));
        let path = dir.join("cache.snap");
        let mk = || {
            let mut d = Driver::new(tiny_cfg());
            d.verbose = false;
            d.threads = 2;
            d.sw_method = SwMethod::Random;
            d.cache_snapshot_path = Some(path.clone());
            d
        };
        // cold run: populates and persists the cache
        let d1 = mk();
        let out1 = d1.run(&dqn(), &GpBackend::Native, 11);
        assert!(path.exists(), "run must leave a snapshot behind");
        assert!(d1.cache.stats().snapshot_loaded == 0);
        // the checkpointed design records where the warm cache lives
        if let Some(best) = &out1.best {
            assert_eq!(best.cache_snapshot.as_deref(), Some(path.display().to_string().as_str()));
        }
        // identical second run: every evaluation replays against the
        // snapshot instead of the simulator
        let d2 = mk();
        let out2 = d2.run(&dqn(), &GpBackend::Native, 11);
        let stats = d2.cache.stats();
        assert!(stats.snapshot_loaded > 0, "second run must load the snapshot: {stats:?}");
        assert!(stats.snapshot_hits > 0, "snapshot entries must serve hits: {stats:?}");
        // warm-start must not change results
        assert_eq!(out1.hw_trace.best_edp.to_bits(), out2.hw_trace.best_edp.to_bits());
        // telemetry surfaces the warm start
        assert!(out2.metrics.report().contains("cache_snapshot_hits="));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_surfaces_cache_telemetry() {
        let mut driver = Driver::new(tiny_cfg());
        driver.verbose = false;
        driver.threads = 2;
        driver.sw_method = SwMethod::Random;
        let out = driver.run(&dqn(), &GpBackend::Native, 9);
        let report = out.metrics.report();
        assert!(report.contains("cache_hits="), "{report}");
        let stats = driver.cache.stats();
        assert!(stats.hits + stats.misses > 0, "evaluations must route through the cache");
    }

    #[test]
    fn run_surfaces_feasibility_telemetry() {
        let mut driver = Driver::new(tiny_cfg());
        driver.verbose = false;
        driver.threads = 2;
        driver.sw_method = SwMethod::Random;
        let out = driver.run(&dqn(), &GpBackend::Native, 21);
        let report = out.metrics.report();
        assert!(report.contains("feas_constructed="), "{report}");
        // every hardware config and software candidate of this run was
        // generated by the feasibility engine: the per-run scoped sinks
        // surface it without global baselines
        use std::sync::atomic::Ordering;
        let constructed = out.metrics.feas_constructed.load(Ordering::Relaxed);
        assert!(constructed > 0, "run must record constructed candidates: {report}");
        // cross-space pruning ran: every sampled hardware config was
        // certified against both DQN layers before evaluation
        assert!(report.contains("prune_certificates="), "{report}");
        let certs = out.metrics.prune_certificates.load(Ordering::Relaxed);
        assert!(certs > 0, "run must certify hardware candidates: {report}");
        // the certificate memo saw every consultation as a hit or a miss
        let hits = out.metrics.prune_cert_hits.load(Ordering::Relaxed);
        let misses = out.metrics.prune_cert_misses.load(Ordering::Relaxed);
        assert!(hits + misses > 0, "certificate store must be consulted: {report}");
        assert!(report.contains("prune_cert_hits="), "{report}");
        // and the raw-draw telemetry reflects construction, not rejection:
        // with one draw per candidate the feasibility rate sits near 1
        let rate = out.metrics.feasibility_rate();
        assert!(rate > 0.5, "constructive sampling must lift the feasibility rate: {rate}");
    }
}
