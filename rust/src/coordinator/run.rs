//! The per-run search state machine: everything one co-design run owns.
//!
//! `Driver::run` used to be a 130-line monolith interleaving space
//! construction, snapshot I/O, trial accounting, checkpointing, and
//! metrics — and it was documented single-tenant, because surrogate /
//! feasibility / delta telemetry were process-global counters diffed
//! against a baseline. This module is the multi-tenant decomposition:
//!
//! * [`JobSpec`] — the complete, self-contained description of one run
//!   (model + nested config + seed + persistence endpoints), the unit
//!   `runtime::jobs::JobScheduler` accepts;
//! * [`RunScope`] — one per-run telemetry sink per subsystem, installed on
//!   every thread that does work for the run, replacing baseline-diffing
//!   of globals (which blends under concurrency);
//! * [`RunStatus`] / [`RunPhase`] — the lock-free progress/cancellation
//!   view a job handle polls;
//! * [`SearchRun`] — the state machine itself: owns the run's pruned
//!   space, trial counter, incumbent/checkpoint logic, and snapshot
//!   endpoints, and consumes itself in [`SearchRun::run`].
//!
//! Determinism contract: [`SearchRun::run`] is a *move*, not a rewrite, of
//! the former `Driver::run` body — same seeding, same evaluation order,
//! same checkpoint/verbose behavior — so the PR-5 fixed-seed e2e traces
//! stay bit-identical, and `Driver::run` is now a thin wrapper (schedule
//! one job, wait). Sharing the evaluation cache and certificate store
//! across concurrent runs cannot move traces either: both memoize pure
//! functions, so a hit returns exactly the bits a fresh computation would.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::driver::{CodesignOutcome, LayerOutcome};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::parallel::{default_threads, parallel_map};
use crate::model::arch::HwConfig;
use crate::model::batch::{AdaptiveChunker, BatchEvaluator};
use crate::model::cache::EvalCache;
use crate::model::delta::telemetry as delta_telemetry;
use crate::model::eval::Evaluator;
use crate::obs::span::{self, Phase, SpanProfiler, SpanStats};
use crate::obs::trace::{RunTracer, TraceConfig};
use crate::opt::config::{BoConfig, NestedConfig, SemiDecoupledConfig};
use crate::opt::hw_search::{self, Chunking, HwMethod, HwTrace};
use crate::opt::semi_decoupled::{self, MappingTable, TableStore};
use crate::opt::sw_search::{self, SearchTrace, SwMethod, SwProblem};
use crate::opt::transfer::{self, TransferPrior};
use crate::space::feasible::telemetry as feas_telemetry;
use crate::space::prune::{CertificateStore, PrunedHwSpace};
use crate::space::sw_space::SwSpace;
use crate::surrogate::gp::GpBackend;
use crate::surrogate::telemetry as gp_telemetry;
use crate::util::rng::Rng;
use crate::util::sync::lock_unpoisoned;
use crate::workloads::eyeriss::eyeriss_resources;
use crate::workloads::specs::ModelSpec;

/// How the outer hardware loop obtains per-config objective values.
#[derive(Clone, Debug)]
pub enum SearchStrategy {
    /// The paper's nested co-design (§4.1): a full software mapping search
    /// inside every outer hardware trial.
    Nested,
    /// Semi-decoupled two-phase search (`opt::semi_decoupled`): phase 1
    /// builds a per-layer mapping table over the certified hardware
    /// lattice (amortized across scheduler jobs through the shared
    /// [`TableStore`]), phase 2 searches against O(1) table lookups and
    /// bounds the optimality gap by exactly re-searching the top-k
    /// finalists. `hw_method` is ignored (the phase-2 loop is BO).
    SemiDecoupled(SemiDecoupledConfig),
    /// Nested search whose surrogates are warm-started from a source
    /// model's observations (`opt::transfer`). `hw_method` is ignored.
    Transfer(TransferPrior),
}

/// Complete description of one co-design run: what to search, how hard,
/// and where to persist. This is the unit the job scheduler accepts; a
/// `JobSpec` plus a seed fully determines the run's trace.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub model: ModelSpec,
    pub ncfg: NestedConfig,
    pub hw_method: HwMethod,
    pub sw_method: SwMethod,
    /// Outer-loop strategy; [`SearchStrategy::Nested`] reproduces the
    /// pre-strategy driver bit-for-bit.
    pub strategy: SearchStrategy,
    /// Worker threads for this run's (config x layer) fan-out.
    pub threads: usize,
    /// Seed of the run's root RNG; per-(config, layer) software searches
    /// derive their seeds from it exactly as the sequential formulation.
    pub seed: u64,
    pub checkpoint_path: Option<PathBuf>,
    /// Cross-process cache persistence: when set, the run warm-starts by
    /// loading this snapshot (if present and fingerprint-compatible) and
    /// saves the cache back to it when the search finishes.
    pub cache_snapshot_path: Option<PathBuf>,
    /// Trace journaling: when set, the run appends JSONL events to
    /// `trace.path` (see `obs::trace`); `None` journals nothing.
    pub trace: Option<TraceConfig>,
    pub verbose: bool,
}

impl JobSpec {
    /// A spec with the driver's defaults: BO outer and inner loops, the
    /// machine's worker-pool width, no persistence, quiet.
    pub fn new(model: ModelSpec, ncfg: NestedConfig, seed: u64) -> Self {
        JobSpec {
            model,
            ncfg,
            hw_method: HwMethod::Bo,
            sw_method: SwMethod::Bo { surrogate: sw_search::SurrogateKind::Gp },
            strategy: SearchStrategy::Nested,
            threads: default_threads(),
            seed,
            checkpoint_path: None,
            cache_snapshot_path: None,
            trace: None,
            verbose: false,
        }
    }
}

/// One per-run telemetry sink per scoped subsystem, plus the run's span
/// profiler. [`RunScope::enter`] installs all four on the calling thread
/// for the duration of a closure; the run state machine enters the scope
/// on the search thread *and* inside every worker-pool job, so a run's
/// surrogate / feasibility / delta events and phase spans accumulate into
/// its own sinks no matter which thread produced them — exact per-run
/// deltas with no global baselines.
#[derive(Debug, Default)]
pub struct RunScope {
    surrogate: Arc<gp_telemetry::Sink>,
    feasibility: Arc<feas_telemetry::Sink>,
    delta: Arc<delta_telemetry::Sink>,
    spans: Arc<SpanProfiler>,
}

impl RunScope {
    pub fn new() -> Self {
        RunScope::default()
    }

    /// Run `f` with all three sinks and the span profiler installed as the
    /// calling thread's active telemetry scope (restored on exit, also on
    /// unwind).
    pub fn enter<R>(&self, f: impl FnOnce() -> R) -> R {
        gp_telemetry::with_scope(&self.surrogate, || {
            feas_telemetry::with_scope(&self.feasibility, || {
                delta_telemetry::with_scope(&self.delta, || {
                    span::with_profiler(&self.spans, f)
                })
            })
        })
    }

    /// This run's surrogate events so far.
    pub fn surrogate_stats(&self) -> gp_telemetry::SurrogateStats {
        self.surrogate.snapshot()
    }

    /// This run's feasibility-engine events so far.
    pub fn feasibility_stats(&self) -> feas_telemetry::FeasibilityStats {
        self.feasibility.snapshot()
    }

    /// This run's delta-evaluation events so far.
    pub fn delta_stats(&self) -> delta_telemetry::DeltaStats {
        self.delta.snapshot()
    }

    /// The run's span profiler (for explicit-handle timing of phases that
    /// run outside the scoped closure, e.g. snapshot IO).
    pub fn span_profiler(&self) -> &SpanProfiler {
        &self.spans
    }

    /// This run's per-phase span snapshot so far.
    pub fn span_stats(&self) -> SpanStats {
        self.spans.stats()
    }

    /// Publish the per-run sink contents into a run's [`Metrics`].
    pub fn record_into(&self, metrics: &Metrics) {
        metrics.record_surrogate(self.surrogate_stats());
        metrics.record_feasibility(self.feasibility_stats());
        metrics.record_delta(self.delta_stats());
    }
}

/// Lifecycle phase of one run, advanced monotonically by [`SearchRun::run`]
/// (except the jump to `Cancelled`, which can happen from any live phase).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum RunPhase {
    /// Accepted, not yet started (queued behind the scheduler's capacity).
    Pending = 0,
    /// Building the pruned space and warm-starting the cache.
    WarmStart = 1,
    /// The nested hardware/software search is executing.
    Searching = 2,
    /// Search done; persisting the cache snapshot and final metrics.
    Persisting = 3,
    Finished = 4,
    Cancelled = 5,
}

impl RunPhase {
    fn from_u8(v: u8) -> RunPhase {
        match v {
            0 => RunPhase::Pending,
            1 => RunPhase::WarmStart,
            2 => RunPhase::Searching,
            3 => RunPhase::Persisting,
            4 => RunPhase::Finished,
            _ => RunPhase::Cancelled,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RunPhase::Pending => "pending",
            RunPhase::WarmStart => "warm-start",
            RunPhase::Searching => "searching",
            RunPhase::Persisting => "persisting",
            RunPhase::Finished => "finished",
            RunPhase::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, RunPhase::Finished | RunPhase::Cancelled)
    }
}

/// Live, lock-free progress/cancellation view of one run, shared between
/// the run state machine and its job handle.
#[derive(Debug)]
pub struct RunStatus {
    phase: AtomicU8,
    trials_done: AtomicU64,
    trials_total: AtomicU64,
    cancel: AtomicBool,
}

impl RunStatus {
    fn new(trials_total: u64) -> Self {
        RunStatus {
            phase: AtomicU8::new(RunPhase::Pending as u8),
            trials_done: AtomicU64::new(0),
            trials_total: AtomicU64::new(trials_total),
            cancel: AtomicBool::new(false),
        }
    }

    pub fn phase(&self) -> RunPhase {
        RunPhase::from_u8(self.phase.load(Ordering::Relaxed))
    }

    /// Hardware trials whose evaluation has completed (or been skipped
    /// after cancellation).
    pub fn trials_done(&self) -> u64 {
        self.trials_done.load(Ordering::Relaxed)
    }

    /// Hardware trials the run was configured for.
    pub fn trials_total(&self) -> u64 {
        self.trials_total.load(Ordering::Relaxed)
    }

    /// Request cancellation: the run stops evaluating at the next batch
    /// boundary (in-flight simulator work is not interrupted) and reports
    /// `cancelled` in its outcome. Idempotent.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    fn set_phase(&self, phase: RunPhase) {
        self.phase.store(phase as u8, Ordering::Relaxed);
    }

    fn add_trials(&self, n: u64) {
        self.trials_done.fetch_add(n, Ordering::Relaxed);
    }
}

/// The (config x layer) fan-out context one hardware batch expands into:
/// everything `evaluate_hardware_batch` needs besides the batch itself.
pub(crate) struct HwBatchCtx<'a> {
    pub model: &'a ModelSpec,
    pub sw_method: SwMethod,
    pub sw_trials: usize,
    pub sw_bo: &'a BoConfig,
    pub threads: usize,
    pub cache: &'a Arc<EvalCache>,
    /// Run scope to install on every worker thread; `None` records into
    /// the process-global default scope only (the baseline/figure paths).
    pub scope: Option<&'a RunScope>,
}

/// Evaluate a batch of hardware configurations: the (config x layer) cross
/// product of software searches runs across the worker pool in one
/// `parallel_map`, so a warmup batch of W configs on an L-layer model
/// exposes W*L-way parallelism instead of L-way. Returns, per config in
/// order, the summed EDP and per-layer best mappings, or None if any layer
/// has no findable mapping (the unknown constraint).
///
/// Seeding matches the sequential formulation: config `i` of the batch
/// behaves as trial `seed_base + i`.
pub(crate) fn evaluate_hardware_batch(
    ctx: &HwBatchCtx<'_>,
    hws: &[HwConfig],
    backend: &GpBackend,
    metrics: &Metrics,
    seed_base: u64,
) -> Vec<Option<(f64, LayerOutcome)>> {
    let resources = eyeriss_resources(ctx.model.num_pes);
    let eval = Evaluator::new(resources.clone());
    let num_layers = ctx.model.layers.len();
    let jobs: Vec<(usize, usize)> = (0..hws.len())
        .flat_map(|hi| (0..num_layers).map(move |li| (hi, li)))
        .collect();
    let backends: Vec<GpBackend> = jobs.iter().map(|_| backend.clone()).collect();
    // Split the thread budget between this fan-out and the nested batch
    // evaluators, so a wide (config x layer) batch doesn't oversubscribe
    // the machine while a narrow one still uses the spare cores inside
    // each software search's candidate batches.
    let inner_threads = (ctx.threads / jobs.len().max(1)).max(1);

    let run_job = |j: usize, hi: usize, li: usize| -> SearchTrace {
        let layer = &ctx.model.layers[li];
        let problem = SwProblem::with_cache(
            SwSpace::new(layer.clone(), hws[hi].clone(), resources.clone()),
            eval.clone(),
            Arc::clone(ctx.cache),
        )
        .with_batch_threads(inner_threads);
        let mut rng = Rng::seed_from_u64((seed_base + hi as u64) ^ (0x9E37 * (li as u64 + 1)));
        let trace = sw_search::search(
            ctx.sw_method,
            &problem,
            ctx.sw_trials,
            ctx.sw_bo,
            &backends[j],
            &mut rng,
        );
        metrics.add_trace(&trace.evals, trace.raw_draws);
        trace
    };
    let traces: Vec<SearchTrace> =
        parallel_map(&jobs, ctx.threads, |j, &(hi, li)| match ctx.scope {
            // worker threads are fresh per batch: install the run's scope
            // on each so its telemetry lands in the per-run sinks
            Some(scope) => scope.enter(|| run_job(j, hi, li)),
            None => run_job(j, hi, li),
        });

    (0..hws.len())
        .map(|hi| {
            let mut total = 0.0;
            let mut layers = Vec::with_capacity(num_layers);
            for li in 0..num_layers {
                let trace = &traces[hi * num_layers + li];
                let m = trace.best_mapping.clone()?; // None => unknown constraint
                total += trace.best_edp;
                layers.push((ctx.model.layers[li].name.clone(), m, trace.best_edp));
            }
            Some((total, layers))
        })
        .collect()
}

/// The state machine for one co-design run. Owns the run's pruned space,
/// trial counter, incumbent/checkpoint logic, snapshot endpoints, scoped
/// telemetry and metrics; consumed by [`SearchRun::run`]. The evaluation
/// cache and certificate store may be shared with other concurrent runs —
/// both memoize pure functions, so sharing never changes results.
pub struct SearchRun {
    spec: JobSpec,
    cache: Arc<EvalCache>,
    certs: Arc<CertificateStore>,
    tables: Arc<TableStore>,
    scope: RunScope,
    metrics: Arc<Metrics>,
    status: Arc<RunStatus>,
}

impl SearchRun {
    /// A run with a private certificate store (the single-job shape).
    pub fn new(spec: JobSpec, cache: Arc<EvalCache>) -> Self {
        SearchRun::with_shared(spec, cache, Arc::new(CertificateStore::default()))
    }

    /// A run whose certificate store is shared with other runs (the
    /// scheduler's shape).
    pub fn with_shared(
        spec: JobSpec,
        cache: Arc<EvalCache>,
        certs: Arc<CertificateStore>,
    ) -> Self {
        let status = Arc::new(RunStatus::new(spec.ncfg.hw_trials as u64));
        SearchRun {
            spec,
            cache,
            certs,
            tables: Arc::new(TableStore::default()),
            scope: RunScope::new(),
            metrics: Metrics::new(),
            status,
        }
    }

    /// Share a mapping-table store with other runs (the scheduler's shape):
    /// semi-decoupled jobs targeting the same (model, config) reuse one
    /// phase-1 table instead of rebuilding it. Sharing cannot change
    /// results — the table's bits depend only on (model, config), never on
    /// which job built it.
    pub fn with_tables(mut self, tables: Arc<TableStore>) -> Self {
        self.tables = tables;
        self
    }

    /// The live progress/cancellation view (shareable before `run`).
    pub fn status(&self) -> Arc<RunStatus> {
        Arc::clone(&self.status)
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn scope(&self) -> &RunScope {
        &self.scope
    }

    /// Execute the run to completion (or cancellation). This body is the
    /// former `Driver::run` — same seeding, same evaluation order, same
    /// checkpoint and logging behavior — with the global telemetry
    /// baselines replaced by the run scope, cancellation checks at batch
    /// boundaries, and checkpoint/snapshot failures counted into metrics.
    pub fn run(self, backend: &GpBackend) -> CodesignOutcome {
        let SearchRun { spec, cache, certs, tables, scope, metrics, status } = self;
        let model = &spec.model;
        let run_id = format!("{}-{}", model.name, spec.seed);
        let mut tracer = match &spec.trace {
            Some(cfg) => RunTracer::create(cfg, &run_id),
            None => RunTracer::disabled(),
        };
        tracer.run_start(
            model.name,
            spec.seed,
            spec.ncfg.hw_trials,
            spec.ncfg.sw_trials,
            spec.threads,
        );
        if status.is_cancelled() {
            status.set_phase(RunPhase::Cancelled);
            scope.record_into(&metrics);
            let span_stats = scope.span_stats();
            tracer.run_end(
                true,
                0,
                0,
                0,
                scope.surrogate_stats(),
                scope.feasibility_stats(),
                scope.delta_stats(),
                None,
                &span_stats,
            );
            metrics.add_trace_io_failures(tracer.io_failures());
            return CodesignOutcome {
                hw_trace: HwTrace::new(),
                best: None,
                metrics,
                cancelled: true,
                spans: span_stats,
            };
        }

        status.set_phase(RunPhase::WarmStart);
        tracer.phase(RunPhase::WarmStart.name());
        // One pruned space per run, shared by the whole hardware search:
        // candidate configs are certified against every layer of the target
        // model and provably-empty ones never reach the simulator. The
        // certificate memo may be shared across runs.
        let space = PrunedHwSpace::with_store(
            eyeriss_resources(model.num_pes),
            model.layers.clone(),
            certs,
        );
        let best: Mutex<Option<Checkpoint>> = Mutex::new(None);
        let mut trial = 0usize;

        // Snapshot endpoint: same resources => same fingerprint as every
        // software search of this run keys its entries under.
        let snapshot_io = BatchEvaluator::with_cache(
            Evaluator::new(eyeriss_resources(model.num_pes)),
            Arc::clone(&cache),
        );
        if let Some(path) = &spec.cache_snapshot_path {
            if path.exists() {
                let loaded = scope
                    .span_profiler()
                    .time(Phase::Checkpoint, || snapshot_io.load_snapshot(path));
                match loaded {
                    Ok(n) => {
                        tracer.snapshot_load(true, n as u64);
                        eprintln!(
                            "[{}] loaded cache snapshot: {n} entries from {}",
                            model.name,
                            path.display()
                        );
                    }
                    // a stale or foreign snapshot degrades to a cold start,
                    // never to wrong results
                    Err(e) => {
                        metrics.record_snapshot_io_failure();
                        tracer.snapshot_load(false, 0);
                        eprintln!("[{}] cache snapshot ignored: {e:#}", model.name);
                    }
                }
            }
        }
        // Size warmup batches from observed latency: one hardware config
        // costs about (sw trials x layers) simulator evaluations.
        let evals_per_config = (spec.ncfg.sw_trials * model.layers.len().max(1)) as f64;
        let chunker = AdaptiveChunker::new(Arc::clone(&cache), evals_per_config);

        status.set_phase(RunPhase::Searching);
        tracer.phase(RunPhase::Searching.name());
        let hw_trace = scope.enter(|| {
            let ctx = HwBatchCtx {
                model,
                sw_method: spec.sw_method,
                sw_trials: spec.ncfg.sw_trials,
                sw_bo: &spec.ncfg.sw_bo,
                threads: spec.threads,
                cache: &cache,
                scope: Some(&scope),
            };
            let mut inner = |hws: &[HwConfig]| -> Vec<Option<f64>> {
                let base = trial;
                trial += hws.len();
                if status.is_cancelled() {
                    // stop evaluating: the search loop keeps its trial
                    // accounting but no simulator work runs past this point
                    status.add_trials(hws.len() as u64);
                    return hws.iter().map(|_| None).collect();
                }
                let outs = scope.span_profiler().time(Phase::Evaluate, || {
                    evaluate_hardware_batch(&ctx, hws, backend, &metrics, spec.seed + base as u64)
                });
                let results: Vec<Option<f64>> = outs
                    .into_iter()
                    .enumerate()
                    .map(|(k, out)| {
                        let t = base + k;
                        status.add_trials(1);
                        if let Some((edp, layers)) = &out {
                            let mut guard = lock_unpoisoned(&best);
                            let improved = guard.as_ref().is_none_or(|b| *edp < b.best_edp);
                            if improved {
                                let ck = Checkpoint {
                                    model: model.name.to_string(),
                                    trial: t,
                                    best_edp: *edp,
                                    cache_snapshot: spec
                                        .cache_snapshot_path
                                        .as_ref()
                                        .map(|p| p.display().to_string()),
                                    hw: hws[k].clone(),
                                    layers: layers.clone(),
                                };
                                let mut checkpointed = false;
                                if let Some(path) = &spec.checkpoint_path {
                                    let saved = scope
                                        .span_profiler()
                                        .time(Phase::Checkpoint, || ck.save(path));
                                    match saved {
                                        Ok(()) => checkpointed = true,
                                        Err(e) => {
                                            metrics.record_checkpoint_save_failure();
                                            eprintln!("checkpoint save failed: {e:#}");
                                        }
                                    }
                                }
                                tracer.incumbent(t as u64, *edp, checkpointed);
                                *guard = Some(ck);
                            }
                            if spec.verbose {
                                let best_edp =
                                    guard.as_ref().map(|b| b.best_edp).unwrap_or(*edp);
                                eprintln!(
                                    "[{}] hw trial {t}: edp {:.3e} (best {:.3e})",
                                    model.name, edp, best_edp
                                );
                            }
                        } else if spec.verbose {
                            eprintln!(
                                "[{}] hw trial {t}: infeasible (no mapping found)",
                                model.name
                            );
                        }
                        out.map(|(edp, _)| edp)
                    })
                    .collect();
                let feasible = results.iter().filter(|r| r.is_some()).count() as u64;
                tracer.batch(
                    base as u64,
                    hws.len() as u64,
                    feasible,
                    scope.surrogate_stats(),
                    scope.feasibility_stats(),
                    scope.delta_stats(),
                    scope.span_profiler(),
                );
                results
            };

            let mut rng = Rng::seed_from_u64(spec.seed);
            match &spec.strategy {
                SearchStrategy::Nested => hw_search::search(
                    spec.hw_method,
                    &space,
                    inner,
                    spec.ncfg.hw_trials,
                    &spec.ncfg.hw_bo,
                    &Chunking::Adaptive(&chunker),
                    backend,
                    &mut rng,
                ),
                SearchStrategy::Transfer(prior) => transfer::search_with_prior(
                    &space,
                    prior,
                    inner,
                    spec.ncfg.hw_trials,
                    &spec.ncfg.hw_bo,
                    &Chunking::Adaptive(&chunker),
                    backend,
                    &mut rng,
                ),
                SearchStrategy::SemiDecoupled(sd) => {
                    // Phase 1: fetch or build the (model, config) mapping
                    // table. Build seeding and evaluation order derive from
                    // the table key alone, so every job sharing the store
                    // would build bit-identical tables — the first to
                    // arrive pays, the rest reuse (their run-scoped
                    // `table_cells` stays 0). Cancellation is deliberately
                    // not checked here: a partially built table must never
                    // be memoized for other jobs, and the build is bounded
                    // by max_cells * cell_sw_trials * layers.
                    let key = semi_decoupled::table_key(model.name, sd);
                    let tseed = semi_decoupled::table_seed(&key);
                    let cell_ctx = HwBatchCtx {
                        model,
                        sw_method: spec.sw_method,
                        sw_trials: sd.cell_sw_trials,
                        sw_bo: &spec.ncfg.sw_bo,
                        threads: spec.threads,
                        cache: &cache,
                        scope: Some(&scope),
                    };
                    let table = tables.get_or_build(&key, || {
                        let mut built = 0u64;
                        MappingTable::build(
                            &space,
                            sd,
                            |hws| {
                                let base = built;
                                built += hws.len() as u64;
                                scope.span_profiler().time(Phase::Evaluate, || {
                                    evaluate_hardware_batch(
                                        &cell_ctx,
                                        hws,
                                        backend,
                                        &metrics,
                                        tseed.wrapping_add(base),
                                    )
                                })
                            },
                            tseed,
                        )
                    });
                    // Phase 2 against lookups; the top-k finalists route
                    // through `inner`, so their exact re-searches get the
                    // full budget plus incumbent/checkpoint/trace handling.
                    let out = semi_decoupled::search(
                        &space,
                        &table,
                        spec.ncfg.hw_trials,
                        sd.topk,
                        &spec.ncfg.hw_bo,
                        &mut inner,
                        backend,
                        &mut rng,
                    );
                    drop(inner); // release its &mut tracer capture
                    let exact_best =
                        out.best_exact.as_ref().map(|(_, e)| *e).unwrap_or(f64::INFINITY);
                    tracer.gap_report(
                        out.finalists.len() as u64,
                        out.gap,
                        out.trace.best_edp,
                        exact_best,
                    );
                    out.trace
                }
            }
        });

        status.set_phase(RunPhase::Persisting);
        tracer.phase(RunPhase::Persisting.name());
        if let Some(path) = &spec.cache_snapshot_path {
            let saved = scope
                .span_profiler()
                .time(Phase::Checkpoint, || snapshot_io.save_snapshot(path));
            match saved {
                Ok(n) => {
                    tracer.snapshot_save(true, n as u64);
                    eprintln!(
                        "[{}] saved cache snapshot: {n} entries to {}",
                        model.name,
                        path.display()
                    );
                }
                Err(e) => {
                    metrics.record_snapshot_io_failure();
                    tracer.snapshot_save(false, 0);
                    eprintln!("[{}] cache snapshot save failed: {e:#}", model.name);
                }
            }
        }
        metrics.record_cache(cache.stats());
        // Read each subsystem's run totals exactly once and feed the same
        // values to both the metrics report and the journal's run_end, so
        // the two reconcile field-for-field.
        let gp = scope.surrogate_stats();
        let feas = scope.feasibility_stats();
        let delta = scope.delta_stats();
        metrics.record_surrogate(gp);
        metrics.record_feasibility(feas);
        metrics.record_delta(delta);
        let cancelled = status.is_cancelled();
        status.set_phase(if cancelled { RunPhase::Cancelled } else { RunPhase::Finished });
        let span_stats = scope.span_stats();
        // cache stats are shared across concurrent jobs, hence excluded
        // from deterministic journals (hit/miss attribution races)
        let cache_for_journal = spec
            .trace
            .as_ref()
            .and_then(|cfg| (!cfg.deterministic).then(|| cache.stats()));
        tracer.run_end(
            cancelled,
            metrics.sim_evals.load(Ordering::Relaxed),
            metrics.raw_draws.load(Ordering::Relaxed),
            metrics.feasible_evals.load(Ordering::Relaxed),
            gp,
            feas,
            delta,
            cache_for_journal,
            &span_stats,
        );
        metrics.add_trace_io_failures(tracer.io_failures());
        let best = best.into_inner().unwrap_or_else(PoisonError::into_inner);
        CodesignOutcome { hw_trace, best, metrics, cancelled, spans: span_stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::config::BoConfig;
    use crate::workloads::specs::dqn;

    fn tiny_spec(seed: u64) -> JobSpec {
        let ncfg = NestedConfig {
            hw_trials: 3,
            sw_trials: 8,
            hw_bo: BoConfig { warmup: 2, pool: 6, ..BoConfig::hardware() },
            sw_bo: BoConfig { warmup: 3, pool: 6, ..BoConfig::software() },
        };
        let mut spec = JobSpec::new(dqn(), ncfg, seed);
        spec.threads = 2;
        spec
    }

    #[test]
    fn run_scope_separates_concurrent_recording() {
        let a = RunScope::new();
        let b = RunScope::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                a.enter(|| {
                    feas_telemetry::record_constructed();
                    feas_telemetry::record_constructed();
                    gp_telemetry::record_extend();
                })
            });
            s.spawn(|| b.enter(feas_telemetry::record_constructed));
        });
        assert_eq!(a.feasibility_stats().constructed, 2);
        assert_eq!(a.surrogate_stats().extends, 1);
        assert_eq!(b.feasibility_stats().constructed, 1);
        assert_eq!(b.surrogate_stats().extends, 0);
    }

    #[test]
    fn search_run_walks_the_phases_and_matches_the_driver_contract() {
        let run = SearchRun::new(tiny_spec(3), Arc::new(EvalCache::default()));
        let status = run.status();
        assert_eq!(status.phase(), RunPhase::Pending);
        assert_eq!(status.trials_total(), 3);
        let out = run.run(&GpBackend::Native);
        assert_eq!(status.phase(), RunPhase::Finished);
        assert!(status.phase().is_terminal());
        assert_eq!(status.trials_done(), 3);
        assert!(!out.cancelled);
        assert_eq!(out.hw_trace.evals.len(), 3);
        // per-run scoped telemetry reached the metrics without baselines
        use std::sync::atomic::Ordering;
        assert!(out.metrics.feas_constructed.load(Ordering::Relaxed) > 0);
        assert!(out.metrics.prune_certificates.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn cancelled_before_start_returns_an_empty_cancelled_outcome() {
        let run = SearchRun::new(tiny_spec(4), Arc::new(EvalCache::default()));
        let status = run.status();
        status.cancel();
        let out = run.run(&GpBackend::Native);
        assert!(out.cancelled);
        assert!(out.best.is_none());
        assert!(out.hw_trace.evals.is_empty());
        assert_eq!(status.phase(), RunPhase::Cancelled);
    }

    #[test]
    fn run_phase_round_trips_through_u8() {
        for phase in [
            RunPhase::Pending,
            RunPhase::WarmStart,
            RunPhase::Searching,
            RunPhase::Persisting,
            RunPhase::Finished,
            RunPhase::Cancelled,
        ] {
            assert_eq!(RunPhase::from_u8(phase as u8), phase);
            assert!(!phase.name().is_empty());
        }
    }
}
