//! The Layer-3 coordinator: nested co-design driver (leader), parallel
//! per-layer workers, run metrics, and checkpointing.

pub mod checkpoint;
pub mod driver;
pub mod metrics;
pub mod parallel;

pub use checkpoint::Checkpoint;
pub use driver::{eyeriss_baseline, CodesignOutcome, Driver};
pub use metrics::Metrics;
pub use parallel::{default_threads, parallel_map};
