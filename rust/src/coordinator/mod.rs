//! The Layer-3 coordinator: per-run search state machine ([`run`]), the
//! thin nested co-design driver facade over it, parallel per-layer
//! workers, run metrics, and checkpointing. Job-level scheduling of many
//! concurrent runs lives in [`crate::runtime::jobs`].

pub mod checkpoint;
pub mod driver;
pub mod metrics;
pub mod parallel;
pub mod run;

pub use checkpoint::Checkpoint;
pub use driver::{eyeriss_baseline, CodesignOutcome, Driver};
pub use metrics::Metrics;
pub use parallel::{default_threads, parallel_map};
pub use run::{JobSpec, RunPhase, RunScope, RunStatus, SearchRun};
