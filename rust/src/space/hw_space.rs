//! Hardware design-space sampling (H1-H12, paper Fig. 6) under the known
//! input constraints of Fig. 7. Since the feasibility engine landed,
//! `sample_valid` is constructive: every Fig. 7 constraint is satisfiable by
//! construction (mesh pairs are factor pairs, the local-buffer partition is
//! a positive composition of the budget, GLB meshes are divisor picks), so
//! a valid configuration costs exactly one draw — and a budget that cannot
//! satisfy them at all is *proved* empty up front instead of spinning the
//! old rejection loop forever. The uniform-with-rejection path survives as
//! `sample_valid_rejection`, the baseline the `feasible_sampling` bench
//! measures against. The unknown mapping-existence constraint is still
//! discovered later by the software search.

use crate::model::arch::{DataflowOpt, HwConfig, Resources};
use crate::space::factors::{divisors, factor_pairs};
use crate::space::feasible::telemetry as feastel;
use crate::util::rng::Rng;

/// The hardware design space for a fixed resource budget.
#[derive(Clone, Debug)]
pub struct HwSpace {
    pub resources: Resources,
}

impl HwSpace {
    pub fn new(resources: Resources) -> Self {
        HwSpace { resources }
    }

    /// One raw sample (uniform over the parameterization). May violate the
    /// known constraints; callers normally use `sample_valid`.
    pub fn sample_raw(&self, rng: &mut Rng) -> HwConfig {
        let res = &self.resources;
        // H1/H2: PE mesh.
        let pairs = factor_pairs(res.num_pes);
        let &(pe_mesh_x, pe_mesh_y) = rng.choose(&pairs);

        // H3-H5: local buffer partition. Sample two cut points over the
        // budget (uniform over compositions), so the partition sums exactly
        // to the budget with occasional zero parts exercising the
        // constraint checker, matching the paper's "0 to #entries" ranges.
        let total = res.local_buffer_entries;
        let a = rng.below(total as usize + 1) as u64;
        let b = rng.below(total as usize + 1) as u64;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (lb_inputs, lb_weights, lb_outputs) = (lo, hi - lo, total - hi);

        // H6-H8: global buffer arrangement. Mesh must divide the PE mesh.
        let gb_mesh_x = *rng.choose(&divisors(pe_mesh_x));
        let gb_mesh_y = *rng.choose(&divisors(pe_mesh_y));
        let gb_instances = gb_mesh_x * gb_mesh_y;

        // H9/H10: entry geometry, factors of 16.
        let geo = [1u64, 2, 4, 8, 16];
        let gb_block = *rng.choose(&geo);
        let gb_cluster = *rng.choose(&geo);

        // H11/H12: dataflow options.
        let df = |rng: &mut Rng| {
            if rng.chance(0.5) {
                DataflowOpt::FullAtPe
            } else {
                DataflowOpt::Streamed
            }
        };

        HwConfig {
            pe_mesh_x,
            pe_mesh_y,
            lb_inputs,
            lb_weights,
            lb_outputs,
            gb_instances,
            gb_mesh_x,
            gb_mesh_y,
            gb_block,
            gb_cluster,
            df_filter_w: df(rng),
            df_filter_h: df(rng),
        }
    }

    /// One configuration that is valid by construction: every Fig. 7
    /// constraint is enforced while drawing, so no rejection is needed.
    /// `None` only when the budget is degenerate (fewer than 3 local-buffer
    /// words cannot hold three non-empty sub-buffers, or zero PEs) — which
    /// is a *proof* that no valid configuration exists at all.
    pub fn sample_feasible(&self, rng: &mut Rng) -> Option<HwConfig> {
        let res = &self.resources;
        if res.num_pes == 0 {
            return None;
        }
        // H1/H2: any factor pair multiplies out to #PEs.
        let pairs = factor_pairs(res.num_pes);
        let &(pe_mesh_x, pe_mesh_y) = rng.choose(&pairs);

        // H3-H5: a positive composition of the budget.
        let (lb_inputs, lb_weights, lb_outputs) =
            positive_partition(rng, res.local_buffer_entries)?;

        // H6-H8: divisors of the mesh always align.
        let gb_mesh_x = *rng.choose(&divisors(pe_mesh_x));
        let gb_mesh_y = *rng.choose(&divisors(pe_mesh_y));

        // H9/H10: factors of 16 by enumeration.
        let geo = [1u64, 2, 4, 8, 16];
        let df = |rng: &mut Rng| {
            if rng.chance(0.5) {
                DataflowOpt::FullAtPe
            } else {
                DataflowOpt::Streamed
            }
        };
        Some(HwConfig {
            pe_mesh_x,
            pe_mesh_y,
            lb_inputs,
            lb_weights,
            lb_outputs,
            gb_instances: gb_mesh_x * gb_mesh_y,
            gb_mesh_x,
            gb_mesh_y,
            gb_block: *rng.choose(&geo),
            gb_cluster: *rng.choose(&geo),
            df_filter_w: df(rng),
            df_filter_h: df(rng),
        })
    }

    /// One valid configuration and the raw draws it cost — always exactly
    /// one, by construction. A budget that [`HwSpace::sample_feasible`]
    /// proves empty panics with a diagnosable message: the pre-engine
    /// behavior was an *infinite* rejection loop (every raw draw fails
    /// `HwConfig::check`), and no caller can make progress without
    /// configurations, so this follows the repo's `Rng::below(0)`
    /// empty-pool-upstream philosophy.
    pub fn sample_valid(&self, rng: &mut Rng) -> (HwConfig, u64) {
        if let Some(cfg) = self.sample_feasible(rng) {
            debug_assert_eq!(cfg.check(&self.resources), Ok(()));
            feastel::record_constructed();
            return (cfg, 1);
        }
        feastel::record_infeasible_space();
        // lint: allow(panic-freedom) — documented config-error contract (see doc comment above)
        panic!(
            "HwSpace::sample_valid: budget (num_pes={}, local_buffer_entries={}) \
             admits no valid configuration",
            self.resources.num_pes, self.resources.local_buffer_entries
        );
    }

    /// The pre-engine path: rejection-sample until the known constraints
    /// pass. Returns the config and the number of raw draws it took (cf.
    /// the paper's ~90% invalid observation); kept as the constructive
    /// sampler's fallback and the bench baseline.
    pub fn sample_valid_rejection(&self, rng: &mut Rng) -> (HwConfig, u64) {
        let mut draws = 0;
        loop {
            draws += 1;
            let cfg = self.sample_raw(rng);
            if cfg.check(&self.resources).is_ok() {
                return (cfg, draws);
            }
        }
    }

    /// Feasibility-preserving mutation: like [`HwSpace::perturb`] but every
    /// move keeps the Fig. 7 constraints intact (the buffer re-partition
    /// stays a positive composition; mesh moves re-align the GLB), so a
    /// valid base yields a valid neighbor without re-checking.
    pub fn perturb_feasible(&self, rng: &mut Rng, base: &HwConfig) -> HwConfig {
        let mut cfg = base.clone();
        match rng.below(5) {
            0 => {
                let pairs = factor_pairs(self.resources.num_pes);
                let &(x, y) = rng.choose(&pairs);
                cfg.pe_mesh_x = x;
                cfg.pe_mesh_y = y;
                if cfg.pe_mesh_x % cfg.gb_mesh_x != 0 || cfg.pe_mesh_y % cfg.gb_mesh_y != 0 {
                    cfg.gb_mesh_x = 1;
                    cfg.gb_mesh_y = 1;
                    cfg.gb_instances = 1;
                }
            }
            1 => {
                if let Some((i, w, o)) =
                    positive_partition(rng, self.resources.local_buffer_entries)
                {
                    cfg.lb_inputs = i;
                    cfg.lb_weights = w;
                    cfg.lb_outputs = o;
                }
            }
            2 => {
                cfg.gb_mesh_x = *rng.choose(&divisors(cfg.pe_mesh_x));
                cfg.gb_mesh_y = *rng.choose(&divisors(cfg.pe_mesh_y));
                cfg.gb_instances = cfg.gb_mesh_x * cfg.gb_mesh_y;
            }
            3 => {
                let geo = [1u64, 2, 4, 8, 16];
                cfg.gb_block = *rng.choose(&geo);
                cfg.gb_cluster = *rng.choose(&geo);
            }
            _ => {
                if rng.chance(0.5) {
                    cfg.df_filter_w = flip(cfg.df_filter_w);
                } else {
                    cfg.df_filter_h = flip(cfg.df_filter_h);
                }
            }
        }
        debug_assert!(
            base.check(&self.resources).is_err() || cfg.check(&self.resources).is_ok(),
            "feasible perturbation left the known-constraint set"
        );
        cfg
    }

    /// Mutate one parameter group of a config (used by the relax-and-round
    /// BO baseline and by local-refinement moves).
    pub fn perturb(&self, rng: &mut Rng, base: &HwConfig) -> HwConfig {
        let mut cfg = base.clone();
        match rng.below(5) {
            0 => {
                let pairs = factor_pairs(self.resources.num_pes);
                let &(x, y) = rng.choose(&pairs);
                cfg.pe_mesh_x = x;
                cfg.pe_mesh_y = y;
                // keep GLB mesh consistent if possible
                if cfg.pe_mesh_x % cfg.gb_mesh_x != 0 || cfg.pe_mesh_y % cfg.gb_mesh_y != 0 {
                    cfg.gb_mesh_x = 1;
                    cfg.gb_mesh_y = 1;
                    cfg.gb_instances = 1;
                }
            }
            1 => {
                let total = self.resources.local_buffer_entries;
                let a = rng.below(total as usize + 1) as u64;
                let b = rng.below(total as usize + 1) as u64;
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                cfg.lb_inputs = lo;
                cfg.lb_weights = hi - lo;
                cfg.lb_outputs = total - hi;
            }
            2 => {
                cfg.gb_mesh_x = *rng.choose(&divisors(cfg.pe_mesh_x));
                cfg.gb_mesh_y = *rng.choose(&divisors(cfg.pe_mesh_y));
                cfg.gb_instances = cfg.gb_mesh_x * cfg.gb_mesh_y;
            }
            3 => {
                let geo = [1u64, 2, 4, 8, 16];
                cfg.gb_block = *rng.choose(&geo);
                cfg.gb_cluster = *rng.choose(&geo);
            }
            _ => {
                if rng.chance(0.5) {
                    cfg.df_filter_w = flip(cfg.df_filter_w);
                } else {
                    cfg.df_filter_h = flip(cfg.df_filter_h);
                }
            }
        }
        cfg
    }
}

fn flip(d: DataflowOpt) -> DataflowOpt {
    match d {
        DataflowOpt::FullAtPe => DataflowOpt::Streamed,
        DataflowOpt::Streamed => DataflowOpt::FullAtPe,
    }
}

/// A uniformly random composition of `total` into three *positive* parts:
/// two distinct cut points in `1..total`, drawn with the distinct-pair
/// shift (second draw over one fewer value, bumped past the first on
/// collision) that keeps the pair uniform without rejection. `None` when
/// `total < 3` — three non-empty parts cannot exist.
fn positive_partition(rng: &mut Rng, total: u64) -> Option<(u64, u64, u64)> {
    if total < 3 {
        return None;
    }
    let c1 = 1 + rng.below(total as usize - 1) as u64;
    let mut c2 = 1 + rng.below(total as usize - 2) as u64;
    if c2 >= c1 {
        c2 += 1;
    }
    let (lo, hi) = if c1 < c2 { (c1, c2) } else { (c2, c1) };
    Some((lo, hi - lo, total - hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_samples_pass_known_constraints() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..200 {
            let (cfg, draws) = space.sample_valid(&mut rng);
            assert_eq!(cfg.check(&space.resources), Ok(()));
            // constructive: a valid config costs exactly one draw
            assert_eq!(draws, 1);
        }
    }

    #[test]
    fn constructive_samples_cover_partitions_and_meshes() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(7);
        let mut partitions = std::collections::HashSet::new();
        for _ in 0..300 {
            let cfg = space.sample_feasible(&mut rng).unwrap();
            assert_eq!(cfg.check(&space.resources), Ok(()));
            assert!(cfg.lb_inputs > 0 && cfg.lb_weights > 0 && cfg.lb_outputs > 0);
            assert_eq!(cfg.local_buffer_used(), space.resources.local_buffer_entries);
            partitions.insert((cfg.lb_inputs, cfg.lb_weights));
        }
        assert!(partitions.len() > 100, "partition diversity: {}", partitions.len());
    }

    #[test]
    fn degenerate_budget_is_proved_empty() {
        let mut res = Resources::eyeriss_168();
        res.local_buffer_entries = 2; // cannot hold three non-empty buffers
        let space = HwSpace::new(res);
        let mut rng = Rng::seed_from_u64(9);
        assert!(space.sample_feasible(&mut rng).is_none());
    }

    #[test]
    #[should_panic(expected = "admits no valid configuration")]
    fn sample_valid_fails_fast_on_an_empty_budget() {
        // The pre-engine behavior was an infinite rejection loop; the
        // constructive sampler proves emptiness and fails diagnosably.
        let mut res = Resources::eyeriss_168();
        res.local_buffer_entries = 2;
        let space = HwSpace::new(res);
        let mut rng = Rng::seed_from_u64(10);
        let _ = space.sample_valid(&mut rng);
    }

    #[test]
    fn perturb_feasible_keeps_known_constraints() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(11);
        let (mut cur, _) = space.sample_valid(&mut rng);
        for _ in 0..200 {
            cur = space.perturb_feasible(&mut rng, &cur);
            assert_eq!(cur.check(&space.resources), Ok(()));
        }
    }

    #[test]
    fn sampler_explores_distinct_meshes() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(2);
        let mut meshes = std::collections::HashSet::new();
        for _ in 0..300 {
            let (cfg, _) = space.sample_valid(&mut rng);
            meshes.insert((cfg.pe_mesh_x, cfg.pe_mesh_y));
        }
        assert!(meshes.len() >= 8, "expected mesh diversity, got {}", meshes.len());
    }

    #[test]
    fn rejection_baseline_rate_is_nontrivial() {
        // Zero-capacity sub-buffers and misaligned meshes make a noticeable
        // fraction of *raw* draws invalid — the cost the constructive
        // sampler avoids (it pays exactly one draw per config).
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(3);
        let mut draws = 0;
        for _ in 0..100 {
            let (cfg, d) = space.sample_valid_rejection(&mut rng);
            assert_eq!(cfg.check(&space.resources), Ok(()));
            draws += d;
        }
        assert!(draws > 100, "some raw draws should be rejected (got {draws})");
    }

    #[test]
    fn perturb_changes_something_and_stays_in_parameterization() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(4);
        let (base, _) = space.sample_valid(&mut rng);
        let mut changed = 0;
        for _ in 0..50 {
            let p = space.perturb(&mut rng, &base);
            if p != base {
                changed += 1;
            }
            // perturbed configs may violate budget sums but never geometry
            assert_eq!(p.pe_mesh_x * p.pe_mesh_y, 168);
            assert_eq!(p.gb_mesh_x * p.gb_mesh_y, p.gb_instances);
        }
        assert!(changed > 30);
    }
}
