//! Hardware design-space sampling (H1-H12, paper Fig. 6) under the known
//! input constraints of Fig. 7. The sampler draws uniformly over the
//! parameterization and rejects violations; `sample_valid` retries until a
//! configuration passes all *known* constraints (the unknown mapping-
//! existence constraint is discovered later by the software search).

use crate::model::arch::{DataflowOpt, HwConfig, Resources};
use crate::space::factors::{divisors, factor_pairs};
use crate::util::rng::Rng;

/// The hardware design space for a fixed resource budget.
#[derive(Clone, Debug)]
pub struct HwSpace {
    pub resources: Resources,
}

impl HwSpace {
    pub fn new(resources: Resources) -> Self {
        HwSpace { resources }
    }

    /// One raw sample (uniform over the parameterization). May violate the
    /// known constraints; callers normally use `sample_valid`.
    pub fn sample_raw(&self, rng: &mut Rng) -> HwConfig {
        let res = &self.resources;
        // H1/H2: PE mesh.
        let pairs = factor_pairs(res.num_pes);
        let &(pe_mesh_x, pe_mesh_y) = rng.choose(&pairs);

        // H3-H5: local buffer partition. Sample two cut points over the
        // budget (uniform over compositions), so the partition sums exactly
        // to the budget with occasional zero parts exercising the
        // constraint checker, matching the paper's "0 to #entries" ranges.
        let total = res.local_buffer_entries;
        let a = rng.below(total as usize + 1) as u64;
        let b = rng.below(total as usize + 1) as u64;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (lb_inputs, lb_weights, lb_outputs) = (lo, hi - lo, total - hi);

        // H6-H8: global buffer arrangement. Mesh must divide the PE mesh.
        let gb_mesh_x = *rng.choose(&divisors(pe_mesh_x));
        let gb_mesh_y = *rng.choose(&divisors(pe_mesh_y));
        let gb_instances = gb_mesh_x * gb_mesh_y;

        // H9/H10: entry geometry, factors of 16.
        let geo = [1u64, 2, 4, 8, 16];
        let gb_block = *rng.choose(&geo);
        let gb_cluster = *rng.choose(&geo);

        // H11/H12: dataflow options.
        let df = |rng: &mut Rng| {
            if rng.chance(0.5) {
                DataflowOpt::FullAtPe
            } else {
                DataflowOpt::Streamed
            }
        };

        HwConfig {
            pe_mesh_x,
            pe_mesh_y,
            lb_inputs,
            lb_weights,
            lb_outputs,
            gb_instances,
            gb_mesh_x,
            gb_mesh_y,
            gb_block,
            gb_cluster,
            df_filter_w: df(rng),
            df_filter_h: df(rng),
        }
    }

    /// Rejection-sample until the known constraints pass. Returns the config
    /// and the number of raw draws it took (used to report the feasibility
    /// ratio, cf. the paper's ~90% invalid observation).
    pub fn sample_valid(&self, rng: &mut Rng) -> (HwConfig, u64) {
        let mut draws = 0;
        loop {
            draws += 1;
            let cfg = self.sample_raw(rng);
            if cfg.check(&self.resources).is_ok() {
                return (cfg, draws);
            }
        }
    }

    /// Mutate one parameter group of a config (used by the relax-and-round
    /// BO baseline and by local-refinement moves).
    pub fn perturb(&self, rng: &mut Rng, base: &HwConfig) -> HwConfig {
        let mut cfg = base.clone();
        match rng.below(5) {
            0 => {
                let pairs = factor_pairs(self.resources.num_pes);
                let &(x, y) = rng.choose(&pairs);
                cfg.pe_mesh_x = x;
                cfg.pe_mesh_y = y;
                // keep GLB mesh consistent if possible
                if cfg.pe_mesh_x % cfg.gb_mesh_x != 0 || cfg.pe_mesh_y % cfg.gb_mesh_y != 0 {
                    cfg.gb_mesh_x = 1;
                    cfg.gb_mesh_y = 1;
                    cfg.gb_instances = 1;
                }
            }
            1 => {
                let total = self.resources.local_buffer_entries;
                let a = rng.below(total as usize + 1) as u64;
                let b = rng.below(total as usize + 1) as u64;
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                cfg.lb_inputs = lo;
                cfg.lb_weights = hi - lo;
                cfg.lb_outputs = total - hi;
            }
            2 => {
                cfg.gb_mesh_x = *rng.choose(&divisors(cfg.pe_mesh_x));
                cfg.gb_mesh_y = *rng.choose(&divisors(cfg.pe_mesh_y));
                cfg.gb_instances = cfg.gb_mesh_x * cfg.gb_mesh_y;
            }
            3 => {
                let geo = [1u64, 2, 4, 8, 16];
                cfg.gb_block = *rng.choose(&geo);
                cfg.gb_cluster = *rng.choose(&geo);
            }
            _ => {
                if rng.chance(0.5) {
                    cfg.df_filter_w = flip(cfg.df_filter_w);
                } else {
                    cfg.df_filter_h = flip(cfg.df_filter_h);
                }
            }
        }
        cfg
    }
}

fn flip(d: DataflowOpt) -> DataflowOpt {
    match d {
        DataflowOpt::FullAtPe => DataflowOpt::Streamed,
        DataflowOpt::Streamed => DataflowOpt::FullAtPe,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_samples_pass_known_constraints() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..200 {
            let (cfg, _) = space.sample_valid(&mut rng);
            assert_eq!(cfg.check(&space.resources), Ok(()));
        }
    }

    #[test]
    fn sampler_explores_distinct_meshes() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(2);
        let mut meshes = std::collections::HashSet::new();
        for _ in 0..300 {
            let (cfg, _) = space.sample_valid(&mut rng);
            meshes.insert((cfg.pe_mesh_x, cfg.pe_mesh_y));
        }
        assert!(meshes.len() >= 8, "expected mesh diversity, got {}", meshes.len());
    }

    #[test]
    fn rejection_rate_is_nontrivial() {
        // Zero-capacity sub-buffers and misaligned meshes should make a
        // noticeable fraction of raw draws invalid.
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(3);
        let mut draws = 0;
        for _ in 0..100 {
            let (_, d) = space.sample_valid(&mut rng);
            draws += d;
        }
        assert!(draws > 100, "some raw draws should be rejected (got {draws})");
    }

    #[test]
    fn perturb_changes_something_and_stays_in_parameterization() {
        let space = HwSpace::new(Resources::eyeriss_168());
        let mut rng = Rng::seed_from_u64(4);
        let (base, _) = space.sample_valid(&mut rng);
        let mut changed = 0;
        for _ in 0..50 {
            let p = space.perturb(&mut rng, &base);
            if p != base {
                changed += 1;
            }
            // perturbed configs may violate budget sums but never geometry
            assert_eq!(p.pe_mesh_x * p.pe_mesh_y, 168);
            assert_eq!(p.gb_mesh_x * p.gb_mesh_y, p.gb_instances);
        }
        assert!(changed > 30);
    }
}
