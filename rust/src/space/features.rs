//! Feature transforms for the BO surrogates (paper Fig. 13 plus the
//! relational features §4.2/§4.3 describe).
//!
//! The paper's GPs use a *linear kernel on explicit features* chosen so that
//! the quantities governing cost (buffer usage ratios, parallelism ratios,
//! mesh ratios, psum-revisit multipliers) appear as coordinates; the linear
//! kernel then encodes those interactions directly and yields the
//! sample-efficient posterior the paper relies on. Both hardware and
//! software points are embedded in the same `FEATURE_DIM`-dimensional space
//! so a single AOT-compiled GP executable serves both searches.

use crate::model::arch::{DataflowOpt, HwConfig, Resources};
use crate::model::energy::effective_glb_capacity;
use crate::model::mapping::{Level, Mapping};
use crate::model::nest::{ds_index, footprint, out_walk, replication, tiles, NestTerms};
use crate::model::workload::{DataSpace, Dim};
use crate::space::sw_space::SwSpace;

/// Shared feature dimensionality (padded; must match the AOT artifacts).
pub const FEATURE_DIM: usize = 16;

fn l2(x: f64) -> f64 {
    (x.max(1e-9)).log2()
}

/// Names for documentation / CSV headers.
pub fn hw_feature_names() -> [&'static str; FEATURE_DIM] {
    [
        "log2_pe_mesh_x",
        "log2_pe_mesh_y",
        "log2_mesh_x_ratio",
        "log2_mesh_y_ratio",
        "lb_inputs_frac",
        "lb_weights_frac",
        "lb_outputs_frac",
        "log2_gb_instances",
        "log2_gb_block",
        "log2_gb_cluster",
        "df_filter_w",
        "df_filter_h",
        "log2_pe_aspect",
        "log2_lb_inputs",
        "log2_lb_weights",
        "log2_lb_outputs",
    ]
}

/// Hardware features (Fig. 13 `mesh_x_ratio` / `mesh_y_ratio` plus the
/// partition and geometry coordinates).
pub fn hw_features(hw: &HwConfig, res: &Resources) -> [f64; FEATURE_DIM] {
    let total = res.local_buffer_entries as f64;
    let flag = |d: DataflowOpt| match d {
        DataflowOpt::FullAtPe => 1.0,
        DataflowOpt::Streamed => 0.0,
    };
    [
        l2(hw.pe_mesh_x as f64),
        l2(hw.pe_mesh_y as f64),
        l2(hw.fanout_x() as f64),
        l2(hw.fanout_y() as f64),
        hw.lb_inputs as f64 / total,
        hw.lb_weights as f64 / total,
        hw.lb_outputs as f64 / total,
        l2(hw.gb_instances as f64),
        l2(hw.gb_block as f64),
        l2(hw.gb_cluster as f64),
        flag(hw.df_filter_w),
        flag(hw.df_filter_h),
        l2(hw.pe_mesh_x as f64 / hw.pe_mesh_y as f64),
        l2(hw.lb_inputs as f64 + 1.0) / 8.0,
        l2(hw.lb_weights as f64 + 1.0) / 8.0,
        l2(hw.lb_outputs as f64 + 1.0) / 8.0,
    ]
}

pub fn sw_feature_names() -> [&'static str; FEATURE_DIM] {
    [
        "input_buffer_usage",
        "weight_buffer_usage",
        "output_buffer_usage",
        "global_buffer_usage",
        "parallelism_ratio_x",
        "parallelism_ratio_y",
        "log2_spatial_used",
        "log2_local_volume",
        "log2_glb_iters",
        "log2_dram_iters",
        "log2_psum_revisit_glb",
        "log2_psum_revisit_all",
        "halo_friendly",
        "glb_fill_inputs",
        "glb_fill_weights",
        "glb_fill_outputs",
    ]
}

/// Software-mapping features (Fig. 13 usage/parallelism ratios plus revisit
/// and residency coordinates computable because hardware is fixed, §4.3).
pub fn sw_features(space: &SwSpace, m: &Mapping) -> [f64; FEATURE_DIM] {
    let layer = &space.layer;
    let hw = &space.hw;
    let t = tiles(layer, m);
    let stride = layer.stride;

    let foot_loc = |ds| footprint(ds, &t.local, stride) as f64;
    let foot_glb = |ds| footprint(ds, &t.glb, stride) as f64;
    let cap = effective_glb_capacity(hw, &space.resources);
    let glb_used: f64 = [DataSpace::Inputs, DataSpace::Weights, DataSpace::Outputs]
        .iter()
        .map(|&ds| foot_glb(ds) * replication(hw, m, ds))
        .sum();

    let spx = m.spatial_x_used() as f64;
    let spy = m.spatial_y_used() as f64;

    let prod_level = |lv: Level| -> f64 {
        m.loops_at(lv).iter().map(|&(_, f)| f as f64).product()
    };

    // psum revisit multipliers (order-sensitive; see nest::out_walk)
    let above_glb: Vec<(Dim, u64)> = m.loops_at(Level::Dram).into_iter().rev().collect();
    let mut above_local: Vec<(Dim, u64)> =
        m.loops_at(Level::Glb).into_iter().rev().collect();
    above_local.extend(above_glb.iter().cloned());
    let w_all = out_walk(&above_local);
    let w_dram = out_walk(&above_glb);

    // halo friendliness: innermost non-1 input-relevant GLB loop is P or Q
    let halo = m
        .loops_at(Level::Glb)
        .iter()
        .rev()
        .find(|&&(d, f)| f > 1 && DataSpace::Inputs.relevant(d))
        .map(|&(d, _)| matches!(d, Dim::P | Dim::Q))
        .unwrap_or(false);

    [
        foot_loc(DataSpace::Inputs) / hw.lb_inputs.max(1) as f64,
        foot_loc(DataSpace::Weights) / hw.lb_weights.max(1) as f64,
        foot_loc(DataSpace::Outputs) / hw.lb_outputs.max(1) as f64,
        glb_used / cap.max(1.0),
        spx / hw.pe_mesh_x as f64,
        spy / hw.pe_mesh_y as f64,
        l2(spx * spy) / 8.0,
        l2(prod_level(Level::Local)) / 8.0,
        l2(prod_level(Level::Glb)) / 8.0,
        l2(prod_level(Level::Dram)) / 16.0,
        l2(w_dram.write_mult / w_dram.distinct.max(1.0)) / 8.0,
        l2(w_all.write_mult / w_all.distinct.max(1.0)) / 8.0,
        if halo { 1.0 } else { 0.0 },
        l2(foot_glb(DataSpace::Inputs) + 1.0) / 16.0,
        l2(foot_glb(DataSpace::Weights) + 1.0) / 16.0,
        l2(foot_glb(DataSpace::Outputs) + 1.0) / 16.0,
    ]
}

/// [`sw_features`] computed from a cached [`NestTerms`] — the delta
/// evaluator's feature fast path (`DeltaEvaluator::terms_for`). The terms
/// hold exactly the footprint/walk/replication values `sw_features` derives
/// from scratch (see `nest::ds_terms`), so the feature vector is
/// bit-identical; only the mapping-local coordinates (spatial products,
/// level iteration products, halo flag) are read off the mapping itself.
pub fn sw_features_from_terms(
    space: &SwSpace,
    m: &Mapping,
    nt: &NestTerms,
) -> [f64; FEATURE_DIM] {
    let hw = &space.hw;
    let foot_loc = |ds| nt.per_ds[ds_index(ds)].foot_loc;
    let foot_glb = |ds| nt.per_ds[ds_index(ds)].foot_glb;
    let cap = effective_glb_capacity(hw, &space.resources);
    let glb_used: f64 = [DataSpace::Inputs, DataSpace::Weights, DataSpace::Outputs]
        .iter()
        .map(|&ds| foot_glb(ds) * nt.per_ds[ds_index(ds)].replication)
        .sum();

    let spx = m.spatial_x_used() as f64;
    let spy = m.spatial_y_used() as f64;

    let prod_level = |lv: Level| -> f64 {
        m.loops_at(lv).iter().map(|&(_, f)| f as f64).product()
    };

    // the Outputs boundary walks *are* the psum revisit multipliers
    let w_all = nt.per_ds[ds_index(DataSpace::Outputs)].walk_a;
    let w_dram = nt.per_ds[ds_index(DataSpace::Outputs)].walk_b;

    // halo friendliness: innermost non-1 input-relevant GLB loop is P or Q
    let halo = m
        .loops_at(Level::Glb)
        .iter()
        .rev()
        .find(|&&(d, f)| f > 1 && DataSpace::Inputs.relevant(d))
        .map(|&(d, _)| matches!(d, Dim::P | Dim::Q))
        .unwrap_or(false);

    [
        foot_loc(DataSpace::Inputs) / hw.lb_inputs.max(1) as f64,
        foot_loc(DataSpace::Weights) / hw.lb_weights.max(1) as f64,
        foot_loc(DataSpace::Outputs) / hw.lb_outputs.max(1) as f64,
        glb_used / cap.max(1.0),
        spx / hw.pe_mesh_x as f64,
        spy / hw.pe_mesh_y as f64,
        l2(spx * spy) / 8.0,
        l2(prod_level(Level::Local)) / 8.0,
        l2(prod_level(Level::Glb)) / 8.0,
        l2(prod_level(Level::Dram)) / 16.0,
        l2(w_dram.write_mult / w_dram.distinct.max(1.0)) / 8.0,
        l2(w_all.write_mult / w_all.distinct.max(1.0)) / 8.0,
        if halo { 1.0 } else { 0.0 },
        l2(foot_glb(DataSpace::Inputs) + 1.0) / 16.0,
        l2(foot_glb(DataSpace::Weights) + 1.0) / 16.0,
        l2(foot_glb(DataSpace::Outputs) + 1.0) / 16.0,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::eyeriss::{eyeriss_hw, eyeriss_resources};
    use crate::workloads::specs::layer_by_name;

    #[test]
    fn hw_features_finite_and_distinguishing() {
        let res = eyeriss_resources(168);
        let a = eyeriss_hw(168);
        let mut b = a.clone();
        b.gb_block = 16;
        b.lb_weights = 100;
        b.lb_inputs = 104;
        let fa = hw_features(&a, &res);
        let fb = hw_features(&b, &res);
        assert!(fa.iter().all(|x| x.is_finite()));
        assert_ne!(fa, fb);
        // mesh ratio features match Fig. 13 semantics
        assert_eq!(fa[2], (14.0f64).log2());
    }

    #[test]
    fn sw_features_finite_for_random_valid_mappings() {
        let sp = SwSpace::new(
            layer_by_name("DQN-K2").unwrap(),
            eyeriss_hw(168),
            eyeriss_resources(168),
        );
        let mut rng = Rng::seed_from_u64(1);
        let mut checked = 0;
        for _ in 0..20 {
            // sampler exhaustion skips the case instead of unwrap-panicking
            let Some((m, _)) = sp.sample_valid(&mut rng, 1_000_000) else { continue };
            let f = sw_features(&sp, &m);
            assert!(f.iter().all(|x| x.is_finite()), "{f:?}");
            // usage ratios of a *valid* mapping are in (0, 1]
            assert!(f[0] > 0.0 && f[0] <= 1.0);
            assert!(f[3] > 0.0 && f[3] <= 1.0);
            assert!(f[4] > 0.0 && f[4] <= 1.0);
            checked += 1;
        }
        assert!(checked > 0, "no feasible mapping sampled at all");
    }

    #[test]
    fn revisit_feature_reflects_order() {
        let sp = SwSpace::new(
            layer_by_name("ResNet-K2").unwrap(),
            eyeriss_hw(168),
            eyeriss_resources(168),
        );
        let l = &sp.layer;
        let mut m = crate::model::mapping::Mapping::trivial(l);
        // order with C innermost at DRAM: no revisit
        m.order_dram = [Dim::P, Dim::Q, Dim::K, Dim::R, Dim::S, Dim::C];
        let f_good = sw_features(&sp, &m);
        // C outermost: heavy revisit
        m.order_dram = [Dim::C, Dim::R, Dim::S, Dim::P, Dim::Q, Dim::K];
        let f_bad = sw_features(&sp, &m);
        assert!(f_bad[11] > f_good[11]);
    }

    #[test]
    fn features_from_terms_are_bit_identical() {
        let sp = SwSpace::new(
            layer_by_name("DQN-K2").unwrap(),
            eyeriss_hw(168),
            eyeriss_resources(168),
        );
        let mut rng = Rng::seed_from_u64(4);
        let mut checked = 0;
        for _ in 0..10 {
            let Some((m, _)) = sp.sample_valid(&mut rng, 1_000_000) else { continue };
            let nt = crate::model::nest::terms(&sp.layer, &sp.hw, &m);
            let scratch = sw_features(&sp, &m);
            let cached = sw_features_from_terms(&sp, &m, &nt);
            for (i, (a, b)) in scratch.iter().zip(cached.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "feature {i} diverged");
            }
            checked += 1;
        }
        assert!(checked > 0, "no feasible mapping sampled at all");
    }

    #[test]
    fn feature_dim_is_stable() {
        // The AOT artifacts are compiled against this dimensionality.
        assert_eq!(FEATURE_DIM, 16);
        assert_eq!(hw_feature_names().len(), FEATURE_DIM);
        assert_eq!(sw_feature_names().len(), FEATURE_DIM);
    }
}
