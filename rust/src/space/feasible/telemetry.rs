//! Process-global feasibility-engine telemetry: monotone counters recording
//! how candidates were obtained — constructed feasibly, perturbed in place,
//! projected from an infeasible point, or recovered through the rejection-
//! sampling fallback — plus infeasible-space detections.
//!
//! The samplers are called from free functions without a `Metrics` handle
//! (the same situation as `crate::surrogate::telemetry`), so the counters
//! live here as statics; `coordinator::metrics` snapshots them at run
//! boundaries and reports the per-run delta via [`FeasibilityStats::since`].
#![deny(clippy::style)]

use std::sync::atomic::{AtomicU64, Ordering};

static CONSTRUCTED: AtomicU64 = AtomicU64::new(0);
static PERTURBATIONS: AtomicU64 = AtomicU64::new(0);
static PERTURBATION_FALLBACKS: AtomicU64 = AtomicU64::new(0);
static PROJECTIONS: AtomicU64 = AtomicU64::new(0);
static PROJECTION_FAILURES: AtomicU64 = AtomicU64::new(0);
static FALLBACK_SAMPLES: AtomicU64 = AtomicU64::new(0);
static FALLBACK_DRAWS: AtomicU64 = AtomicU64::new(0);
static INFEASIBLE_SPACES: AtomicU64 = AtomicU64::new(0);
static DEGRADED_SKIPS: AtomicU64 = AtomicU64::new(0);
static PRUNE_CERTIFICATES: AtomicU64 = AtomicU64::new(0);
static PRUNE_REJECTIONS: AtomicU64 = AtomicU64::new(0);
static LATTICE_BOXES: AtomicU64 = AtomicU64::new(0);
static LATTICE_BOX_SHRINK_MILLI: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the feasibility counters. All fields are totals since process
/// start; use [`FeasibilityStats::since`] to attribute movement to one run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeasibilityStats {
    /// Candidates generated valid-by-construction (one raw draw each).
    pub constructed: u64,
    /// Feasibility-preserving perturbations delivered by the intended move
    /// mixture (a re-derived dimension, or the deliberate order-swap arm).
    pub perturbations: u64,
    /// Perturbations that *degraded* to an order swap: the resplit reset
    /// was refused, its cross-check failed, or the space admits no
    /// propagation. Zero on a healthy constructive space.
    pub perturbation_fallbacks: u64,
    /// Infeasible points snapped onto a feasible mapping by projection.
    pub projections: u64,
    /// Projections that failed because the space admits no construction.
    pub projection_failures: u64,
    /// Valid samples that had to come from the rejection-sampling fallback.
    pub fallback_samples: u64,
    /// Raw draws burned inside the rejection fallback (exhausted included).
    pub fallback_draws: u64,
    /// Spaces detected as unsampleable (provably empty, or the fallback
    /// exhausted its draw budget) — the paper's unknown-constraint signal.
    pub infeasible_spaces: u64,
    /// Search-loop degradations: a consumer skipped, truncated or gave up
    /// on planned work because `sample_valid` could not produce a candidate
    /// (warmup cut short, a pool left partially filled, an SA walker or
    /// hill-climb abandoned). Zero on healthy constructive spaces.
    pub degraded_skips: u64,
    /// Per-layer feasibility certificates computed by the cross-space
    /// pruner (`space::prune::PrunedHwSpace`).
    pub prune_certificates: u64,
    /// Hardware configurations rejected *before* any simulator evaluation
    /// because a certificate proved some target layer's mapping space empty.
    pub prune_rejections: u64,
    /// Lattice-derived relaxation boxes handed to round-BO
    /// (`BoConfig::lattice_box`).
    pub lattice_boxes: u64,
    /// Accumulated box-volume shrink factor of those lattice boxes vs the
    /// raw divisor box, in thousandths (saturating; divide by
    /// `1000 * lattice_boxes` for the mean shrink).
    pub lattice_box_shrink_milli: u64,
}

impl FeasibilityStats {
    /// Counter movement since an earlier snapshot.
    pub fn since(&self, earlier: &FeasibilityStats) -> FeasibilityStats {
        FeasibilityStats {
            constructed: self.constructed.saturating_sub(earlier.constructed),
            perturbations: self.perturbations.saturating_sub(earlier.perturbations),
            perturbation_fallbacks: self
                .perturbation_fallbacks
                .saturating_sub(earlier.perturbation_fallbacks),
            projections: self.projections.saturating_sub(earlier.projections),
            projection_failures: self
                .projection_failures
                .saturating_sub(earlier.projection_failures),
            fallback_samples: self.fallback_samples.saturating_sub(earlier.fallback_samples),
            fallback_draws: self.fallback_draws.saturating_sub(earlier.fallback_draws),
            infeasible_spaces: self.infeasible_spaces.saturating_sub(earlier.infeasible_spaces),
            degraded_skips: self.degraded_skips.saturating_sub(earlier.degraded_skips),
            prune_certificates: self
                .prune_certificates
                .saturating_sub(earlier.prune_certificates),
            prune_rejections: self.prune_rejections.saturating_sub(earlier.prune_rejections),
            lattice_boxes: self.lattice_boxes.saturating_sub(earlier.lattice_boxes),
            lattice_box_shrink_milli: self
                .lattice_box_shrink_milli
                .saturating_sub(earlier.lattice_box_shrink_milli),
        }
    }
}

/// Read all counters.
pub fn snapshot() -> FeasibilityStats {
    FeasibilityStats {
        constructed: CONSTRUCTED.load(Ordering::Relaxed),
        perturbations: PERTURBATIONS.load(Ordering::Relaxed),
        perturbation_fallbacks: PERTURBATION_FALLBACKS.load(Ordering::Relaxed),
        projections: PROJECTIONS.load(Ordering::Relaxed),
        projection_failures: PROJECTION_FAILURES.load(Ordering::Relaxed),
        fallback_samples: FALLBACK_SAMPLES.load(Ordering::Relaxed),
        fallback_draws: FALLBACK_DRAWS.load(Ordering::Relaxed),
        infeasible_spaces: INFEASIBLE_SPACES.load(Ordering::Relaxed),
        degraded_skips: DEGRADED_SKIPS.load(Ordering::Relaxed),
        prune_certificates: PRUNE_CERTIFICATES.load(Ordering::Relaxed),
        prune_rejections: PRUNE_REJECTIONS.load(Ordering::Relaxed),
        lattice_boxes: LATTICE_BOXES.load(Ordering::Relaxed),
        lattice_box_shrink_milli: LATTICE_BOX_SHRINK_MILLI.load(Ordering::Relaxed),
    }
}

/// A candidate was generated valid-by-construction.
pub fn record_constructed() {
    CONSTRUCTED.fetch_add(1, Ordering::Relaxed);
}

/// A perturbation was delivered by the intended move mixture.
pub fn record_perturbation() {
    PERTURBATIONS.fetch_add(1, Ordering::Relaxed);
}

/// A perturbation *degraded* to the always-safe loop-order swap.
pub fn record_perturbation_fallback() {
    PERTURBATION_FALLBACKS.fetch_add(1, Ordering::Relaxed);
}

/// An infeasible point was projected onto a feasible mapping.
pub fn record_projection() {
    PROJECTIONS.fetch_add(1, Ordering::Relaxed);
}

/// A projection failed (no construction exists for the space).
pub fn record_projection_failure() {
    PROJECTION_FAILURES.fetch_add(1, Ordering::Relaxed);
}

/// The rejection fallback produced a valid sample after `draws` raw draws.
pub fn record_fallback_sample(draws: u64) {
    FALLBACK_SAMPLES.fetch_add(1, Ordering::Relaxed);
    FALLBACK_DRAWS.fetch_add(draws, Ordering::Relaxed);
}

/// The rejection fallback exhausted its budget without a valid sample.
pub fn record_fallback_exhausted(draws: u64) {
    FALLBACK_DRAWS.fetch_add(draws, Ordering::Relaxed);
}

/// A space was detected as unsampleable.
pub fn record_infeasible_space() {
    INFEASIBLE_SPACES.fetch_add(1, Ordering::Relaxed);
}

/// A search loop skipped or truncated planned work because no candidate
/// could be sampled (the consumer-side degradation the space-level counters
/// cannot attribute).
pub fn record_degraded_skip() {
    DEGRADED_SKIPS.fetch_add(1, Ordering::Relaxed);
}

/// `n` per-layer feasibility certificates were computed by the cross-space
/// pruner.
pub fn record_certificates(n: u64) {
    PRUNE_CERTIFICATES.fetch_add(n, Ordering::Relaxed);
}

/// A hardware configuration was rejected before evaluation on a
/// provably-empty certificate.
pub fn record_prune_rejection() {
    PRUNE_REJECTIONS.fetch_add(1, Ordering::Relaxed);
}

/// A lattice-derived relaxation box was handed to round-BO; `shrink` is its
/// volume reduction vs the raw divisor box (>= 1, capped so the milli
/// accumulator cannot overflow).
pub fn record_lattice_box(shrink: f64) {
    LATTICE_BOXES.fetch_add(1, Ordering::Relaxed);
    let milli = (shrink.clamp(1.0, 1e12) * 1000.0) as u64;
    LATTICE_BOX_SHRINK_MILLI.fetch_add(milli, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone_and_attributable() {
        // Tests run in parallel and the counters are process-global, so
        // assert on deltas (>=), never on absolute values.
        let before = snapshot();
        record_constructed();
        record_perturbation();
        record_perturbation_fallback();
        record_projection();
        record_projection_failure();
        record_fallback_sample(42);
        record_fallback_exhausted(8);
        record_infeasible_space();
        record_degraded_skip();
        record_certificates(3);
        record_prune_rejection();
        record_lattice_box(2.5);
        let delta = snapshot().since(&before);
        assert!(delta.constructed >= 1);
        assert!(delta.perturbations >= 1);
        assert!(delta.perturbation_fallbacks >= 1);
        assert!(delta.projections >= 1);
        assert!(delta.projection_failures >= 1);
        assert!(delta.fallback_samples >= 1);
        assert!(delta.fallback_draws >= 50);
        assert!(delta.infeasible_spaces >= 1);
        assert!(delta.degraded_skips >= 1);
        assert!(delta.prune_certificates >= 3);
        assert!(delta.prune_rejections >= 1);
        assert!(delta.lattice_boxes >= 1);
        assert!(delta.lattice_box_shrink_milli >= 2500);
    }

    #[test]
    fn lattice_box_shrink_saturates_instead_of_overflowing() {
        let before = snapshot();
        // a pathological shrink factor must clamp, not wrap the accumulator
        record_lattice_box(f64::INFINITY);
        record_lattice_box(0.1); // sub-1 shrink is clamped up to the floor
        let delta = snapshot().since(&before);
        assert!(delta.lattice_boxes >= 2);
        assert!(delta.lattice_box_shrink_milli >= 1_000_000_000_000_000 + 1000);
    }

    #[test]
    fn since_saturates() {
        let a = FeasibilityStats { constructed: 5, ..FeasibilityStats::default() };
        let b = FeasibilityStats { constructed: 9, ..FeasibilityStats::default() };
        assert_eq!(b.since(&a).constructed, 4);
        assert_eq!(a.since(&b).constructed, 0);
    }
}
