//! Process-global feasibility-engine telemetry: monotone counters recording
//! how candidates were obtained — constructed feasibly, perturbed in place,
//! projected from an infeasible point, or recovered through the rejection-
//! sampling fallback — plus infeasible-space detections.
//!
//! The samplers are called from free functions without a `Metrics` handle
//! (the same situation as `crate::surrogate::telemetry`), so the counters
//! live here as statics; `coordinator::metrics` snapshots them at run
//! boundaries and reports the per-run delta via [`FeasibilityStats::since`].
#![deny(clippy::style)]

use std::sync::atomic::{AtomicU64, Ordering};

static CONSTRUCTED: AtomicU64 = AtomicU64::new(0);
static PERTURBATIONS: AtomicU64 = AtomicU64::new(0);
static PERTURBATION_FALLBACKS: AtomicU64 = AtomicU64::new(0);
static PROJECTIONS: AtomicU64 = AtomicU64::new(0);
static PROJECTION_FAILURES: AtomicU64 = AtomicU64::new(0);
static FALLBACK_SAMPLES: AtomicU64 = AtomicU64::new(0);
static FALLBACK_DRAWS: AtomicU64 = AtomicU64::new(0);
static INFEASIBLE_SPACES: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the feasibility counters. All fields are totals since process
/// start; use [`FeasibilityStats::since`] to attribute movement to one run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeasibilityStats {
    /// Candidates generated valid-by-construction (one raw draw each).
    pub constructed: u64,
    /// Feasibility-preserving perturbations delivered by the intended move
    /// mixture (a re-derived dimension, or the deliberate order-swap arm).
    pub perturbations: u64,
    /// Perturbations that *degraded* to an order swap: the resplit reset
    /// was refused, its cross-check failed, or the space admits no
    /// propagation. Zero on a healthy constructive space.
    pub perturbation_fallbacks: u64,
    /// Infeasible points snapped onto a feasible mapping by projection.
    pub projections: u64,
    /// Projections that failed because the space admits no construction.
    pub projection_failures: u64,
    /// Valid samples that had to come from the rejection-sampling fallback.
    pub fallback_samples: u64,
    /// Raw draws burned inside the rejection fallback (exhausted included).
    pub fallback_draws: u64,
    /// Spaces detected as unsampleable (provably empty, or the fallback
    /// exhausted its draw budget) — the paper's unknown-constraint signal.
    pub infeasible_spaces: u64,
}

impl FeasibilityStats {
    /// Counter movement since an earlier snapshot.
    pub fn since(&self, earlier: &FeasibilityStats) -> FeasibilityStats {
        FeasibilityStats {
            constructed: self.constructed.saturating_sub(earlier.constructed),
            perturbations: self.perturbations.saturating_sub(earlier.perturbations),
            perturbation_fallbacks: self
                .perturbation_fallbacks
                .saturating_sub(earlier.perturbation_fallbacks),
            projections: self.projections.saturating_sub(earlier.projections),
            projection_failures: self
                .projection_failures
                .saturating_sub(earlier.projection_failures),
            fallback_samples: self.fallback_samples.saturating_sub(earlier.fallback_samples),
            fallback_draws: self.fallback_draws.saturating_sub(earlier.fallback_draws),
            infeasible_spaces: self.infeasible_spaces.saturating_sub(earlier.infeasible_spaces),
        }
    }
}

/// Read all counters.
pub fn snapshot() -> FeasibilityStats {
    FeasibilityStats {
        constructed: CONSTRUCTED.load(Ordering::Relaxed),
        perturbations: PERTURBATIONS.load(Ordering::Relaxed),
        perturbation_fallbacks: PERTURBATION_FALLBACKS.load(Ordering::Relaxed),
        projections: PROJECTIONS.load(Ordering::Relaxed),
        projection_failures: PROJECTION_FAILURES.load(Ordering::Relaxed),
        fallback_samples: FALLBACK_SAMPLES.load(Ordering::Relaxed),
        fallback_draws: FALLBACK_DRAWS.load(Ordering::Relaxed),
        infeasible_spaces: INFEASIBLE_SPACES.load(Ordering::Relaxed),
    }
}

/// A candidate was generated valid-by-construction.
pub fn record_constructed() {
    CONSTRUCTED.fetch_add(1, Ordering::Relaxed);
}

/// A perturbation was delivered by the intended move mixture.
pub fn record_perturbation() {
    PERTURBATIONS.fetch_add(1, Ordering::Relaxed);
}

/// A perturbation *degraded* to the always-safe loop-order swap.
pub fn record_perturbation_fallback() {
    PERTURBATION_FALLBACKS.fetch_add(1, Ordering::Relaxed);
}

/// An infeasible point was projected onto a feasible mapping.
pub fn record_projection() {
    PROJECTIONS.fetch_add(1, Ordering::Relaxed);
}

/// A projection failed (no construction exists for the space).
pub fn record_projection_failure() {
    PROJECTION_FAILURES.fetch_add(1, Ordering::Relaxed);
}

/// The rejection fallback produced a valid sample after `draws` raw draws.
pub fn record_fallback_sample(draws: u64) {
    FALLBACK_SAMPLES.fetch_add(1, Ordering::Relaxed);
    FALLBACK_DRAWS.fetch_add(draws, Ordering::Relaxed);
}

/// The rejection fallback exhausted its budget without a valid sample.
pub fn record_fallback_exhausted(draws: u64) {
    FALLBACK_DRAWS.fetch_add(draws, Ordering::Relaxed);
}

/// A space was detected as unsampleable.
pub fn record_infeasible_space() {
    INFEASIBLE_SPACES.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone_and_attributable() {
        // Tests run in parallel and the counters are process-global, so
        // assert on deltas (>=), never on absolute values.
        let before = snapshot();
        record_constructed();
        record_perturbation();
        record_perturbation_fallback();
        record_projection();
        record_projection_failure();
        record_fallback_sample(42);
        record_fallback_exhausted(8);
        record_infeasible_space();
        let delta = snapshot().since(&before);
        assert!(delta.constructed >= 1);
        assert!(delta.perturbations >= 1);
        assert!(delta.perturbation_fallbacks >= 1);
        assert!(delta.projections >= 1);
        assert!(delta.projection_failures >= 1);
        assert!(delta.fallback_samples >= 1);
        assert!(delta.fallback_draws >= 50);
        assert!(delta.infeasible_spaces >= 1);
    }

    #[test]
    fn since_saturates() {
        let a = FeasibilityStats { constructed: 5, ..FeasibilityStats::default() };
        let b = FeasibilityStats { constructed: 9, ..FeasibilityStats::default() };
        assert_eq!(b.since(&a).constructed, 4);
        assert_eq!(a.since(&b).constructed, 0);
    }
}
