//! Feasibility-engine telemetry: monotone counters recording how candidates
//! were obtained — constructed feasibly, perturbed in place, projected from
//! an infeasible point, or recovered through the rejection-sampling
//! fallback — plus infeasible-space detections and the cross-space pruner's
//! certificate traffic.
//!
//! The samplers are called from free functions without a `Metrics` handle
//! (the same situation as `crate::surrogate::telemetry`), so recording goes
//! through this module. Every event lands in up to two scopes: the
//! **process-global default scope** (a static [`Sink`], which [`snapshot`]
//! reads — existing call sites and tests keep working unchanged) and at
//! most one per-thread **run scope** installed by [`with_scope`], giving
//! concurrent jobs exact per-run deltas without baseline-diffing globals.
//! Nested scopes shadow; the previous scope is restored on exit and on
//! unwind.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Accumulator for one telemetry scope: either the process-global default
/// or a per-run sink installed via [`with_scope`].
#[derive(Debug, Default)]
pub struct Sink {
    constructed: AtomicU64,
    perturbations: AtomicU64,
    perturbation_fallbacks: AtomicU64,
    projections: AtomicU64,
    projection_failures: AtomicU64,
    fallback_samples: AtomicU64,
    fallback_draws: AtomicU64,
    infeasible_spaces: AtomicU64,
    degraded_skips: AtomicU64,
    prune_certificates: AtomicU64,
    prune_rejections: AtomicU64,
    cert_hits: AtomicU64,
    cert_misses: AtomicU64,
    lattice_boxes: AtomicU64,
    lattice_box_shrink_milli: AtomicU64,
    table_cells: AtomicU64,
    table_hits: AtomicU64,
    gap_resolved: AtomicU64,
}

impl Sink {
    const fn new() -> Self {
        Sink {
            constructed: AtomicU64::new(0),
            perturbations: AtomicU64::new(0),
            perturbation_fallbacks: AtomicU64::new(0),
            projections: AtomicU64::new(0),
            projection_failures: AtomicU64::new(0),
            fallback_samples: AtomicU64::new(0),
            fallback_draws: AtomicU64::new(0),
            infeasible_spaces: AtomicU64::new(0),
            degraded_skips: AtomicU64::new(0),
            prune_certificates: AtomicU64::new(0),
            prune_rejections: AtomicU64::new(0),
            cert_hits: AtomicU64::new(0),
            cert_misses: AtomicU64::new(0),
            lattice_boxes: AtomicU64::new(0),
            lattice_box_shrink_milli: AtomicU64::new(0),
            table_cells: AtomicU64::new(0),
            table_hits: AtomicU64::new(0),
            gap_resolved: AtomicU64::new(0),
        }
    }

    /// Read this scope's counters.
    pub fn snapshot(&self) -> FeasibilityStats {
        FeasibilityStats {
            constructed: self.constructed.load(Ordering::Relaxed),
            perturbations: self.perturbations.load(Ordering::Relaxed),
            perturbation_fallbacks: self.perturbation_fallbacks.load(Ordering::Relaxed),
            projections: self.projections.load(Ordering::Relaxed),
            projection_failures: self.projection_failures.load(Ordering::Relaxed),
            fallback_samples: self.fallback_samples.load(Ordering::Relaxed),
            fallback_draws: self.fallback_draws.load(Ordering::Relaxed),
            infeasible_spaces: self.infeasible_spaces.load(Ordering::Relaxed),
            degraded_skips: self.degraded_skips.load(Ordering::Relaxed),
            prune_certificates: self.prune_certificates.load(Ordering::Relaxed),
            prune_rejections: self.prune_rejections.load(Ordering::Relaxed),
            cert_hits: self.cert_hits.load(Ordering::Relaxed),
            cert_misses: self.cert_misses.load(Ordering::Relaxed),
            lattice_boxes: self.lattice_boxes.load(Ordering::Relaxed),
            lattice_box_shrink_milli: self.lattice_box_shrink_milli.load(Ordering::Relaxed),
            table_cells: self.table_cells.load(Ordering::Relaxed),
            table_hits: self.table_hits.load(Ordering::Relaxed),
            gap_resolved: self.gap_resolved.load(Ordering::Relaxed),
        }
    }
}

/// The process-global default scope.
static GLOBAL: Sink = Sink::new();

thread_local! {
    static ACTIVE: RefCell<Option<Arc<Sink>>> = const { RefCell::new(None) };
}

struct ScopeGuard {
    prev: Option<Arc<Sink>>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        ACTIVE.with(|a| *a.borrow_mut() = self.prev.take());
    }
}

/// Install `sink` as the calling thread's run scope for the duration of
/// `f`: every event recorded by `f` (on this thread) is accumulated into
/// `sink` in addition to the process-global default scope. The previously
/// installed scope, if any, is shadowed and restored on exit.
pub fn with_scope<R>(sink: &Arc<Sink>, f: impl FnOnce() -> R) -> R {
    let prev = ACTIVE.with(|a| a.borrow_mut().replace(Arc::clone(sink)));
    let _guard = ScopeGuard { prev };
    f()
}

/// Apply one recording to every scope that should observe it.
fn record(apply: impl Fn(&Sink)) {
    apply(&GLOBAL);
    ACTIVE.with(|a| {
        if let Some(sink) = a.borrow().as_ref() {
            apply(sink);
        }
    });
}

/// Snapshot of the feasibility counters. Fields read from the global scope
/// are totals since process start; use [`FeasibilityStats::since`] to
/// attribute movement to one window, or read a run scope's [`Sink`]
/// directly for an exact per-run view.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FeasibilityStats {
    /// Candidates generated valid-by-construction (one raw draw each).
    pub constructed: u64,
    /// Feasibility-preserving perturbations delivered by the intended move
    /// mixture (a re-derived dimension, or the deliberate order-swap arm).
    pub perturbations: u64,
    /// Perturbations that *degraded* to an order swap: the resplit reset
    /// was refused, its cross-check failed, or the space admits no
    /// propagation. Zero on a healthy constructive space.
    pub perturbation_fallbacks: u64,
    /// Infeasible points snapped onto a feasible mapping by projection.
    pub projections: u64,
    /// Projections that failed because the space admits no construction.
    pub projection_failures: u64,
    /// Valid samples that had to come from the rejection-sampling fallback.
    pub fallback_samples: u64,
    /// Raw draws burned inside the rejection fallback (exhausted included).
    pub fallback_draws: u64,
    /// Spaces detected as unsampleable (provably empty, or the fallback
    /// exhausted its draw budget) — the paper's unknown-constraint signal.
    pub infeasible_spaces: u64,
    /// Search-loop degradations: a consumer skipped, truncated or gave up
    /// on planned work because `sample_valid` could not produce a candidate
    /// (warmup cut short, a pool left partially filled, an SA walker or
    /// hill-climb abandoned). Zero on healthy constructive spaces.
    pub degraded_skips: u64,
    /// Per-layer feasibility certificates consulted by the cross-space
    /// pruner (`space::prune::PrunedHwSpace`), memoized or not.
    pub prune_certificates: u64,
    /// Hardware configurations rejected *before* any simulator evaluation
    /// because a certificate proved some target layer's mapping space empty.
    pub prune_rejections: u64,
    /// Certificate-store lookups served from the shared memo
    /// (`space::prune::CertificateStore`) without recomputation.
    pub cert_hits: u64,
    /// Certificate-store lookups that computed (and then shared) a new
    /// certificate.
    pub cert_misses: u64,
    /// Lattice-derived relaxation boxes handed to round-BO
    /// (`BoConfig::lattice_box`).
    pub lattice_boxes: u64,
    /// Accumulated box-volume shrink factor of those lattice boxes vs the
    /// raw divisor box, in thousandths (saturating; divide by
    /// `1000 * lattice_boxes` for the mean shrink).
    pub lattice_box_shrink_milli: u64,
    /// Certified-nonempty lattice cells *built* into per-layer mapping
    /// tables by the semi-decoupled strategy (`opt::semi_decoupled`). A run
    /// that reuses a table shared by an earlier job records zero here —
    /// the build cost amortized away.
    pub table_cells: u64,
    /// Outer-loop hardware evaluations served as O(1) mapping-table lookups
    /// instead of nested software searches.
    pub table_hits: u64,
    /// Top-k finalists re-searched exactly to bound the semi-decoupled
    /// optimality gap.
    pub gap_resolved: u64,
}

impl FeasibilityStats {
    /// Counter movement since an earlier snapshot.
    pub fn since(&self, earlier: &FeasibilityStats) -> FeasibilityStats {
        FeasibilityStats {
            constructed: self.constructed.saturating_sub(earlier.constructed),
            perturbations: self.perturbations.saturating_sub(earlier.perturbations),
            perturbation_fallbacks: self
                .perturbation_fallbacks
                .saturating_sub(earlier.perturbation_fallbacks),
            projections: self.projections.saturating_sub(earlier.projections),
            projection_failures: self
                .projection_failures
                .saturating_sub(earlier.projection_failures),
            fallback_samples: self.fallback_samples.saturating_sub(earlier.fallback_samples),
            fallback_draws: self.fallback_draws.saturating_sub(earlier.fallback_draws),
            infeasible_spaces: self.infeasible_spaces.saturating_sub(earlier.infeasible_spaces),
            degraded_skips: self.degraded_skips.saturating_sub(earlier.degraded_skips),
            prune_certificates: self
                .prune_certificates
                .saturating_sub(earlier.prune_certificates),
            prune_rejections: self.prune_rejections.saturating_sub(earlier.prune_rejections),
            cert_hits: self.cert_hits.saturating_sub(earlier.cert_hits),
            cert_misses: self.cert_misses.saturating_sub(earlier.cert_misses),
            lattice_boxes: self.lattice_boxes.saturating_sub(earlier.lattice_boxes),
            lattice_box_shrink_milli: self
                .lattice_box_shrink_milli
                .saturating_sub(earlier.lattice_box_shrink_milli),
            table_cells: self.table_cells.saturating_sub(earlier.table_cells),
            table_hits: self.table_hits.saturating_sub(earlier.table_hits),
            gap_resolved: self.gap_resolved.saturating_sub(earlier.gap_resolved),
        }
    }
}

/// Read all counters of the process-global default scope.
pub fn snapshot() -> FeasibilityStats {
    GLOBAL.snapshot()
}

/// A candidate was generated valid-by-construction.
pub fn record_constructed() {
    record(|s| {
        s.constructed.fetch_add(1, Ordering::Relaxed);
    });
}

/// A perturbation was delivered by the intended move mixture.
pub fn record_perturbation() {
    record(|s| {
        s.perturbations.fetch_add(1, Ordering::Relaxed);
    });
}

/// A perturbation *degraded* to the always-safe loop-order swap.
pub fn record_perturbation_fallback() {
    record(|s| {
        s.perturbation_fallbacks.fetch_add(1, Ordering::Relaxed);
    });
}

/// An infeasible point was projected onto a feasible mapping.
pub fn record_projection() {
    record(|s| {
        s.projections.fetch_add(1, Ordering::Relaxed);
    });
}

/// A projection failed (no construction exists for the space).
pub fn record_projection_failure() {
    record(|s| {
        s.projection_failures.fetch_add(1, Ordering::Relaxed);
    });
}

/// The rejection fallback produced a valid sample after `draws` raw draws.
pub fn record_fallback_sample(draws: u64) {
    record(|s| {
        s.fallback_samples.fetch_add(1, Ordering::Relaxed);
        s.fallback_draws.fetch_add(draws, Ordering::Relaxed);
    });
}

/// The rejection fallback exhausted its budget without a valid sample.
pub fn record_fallback_exhausted(draws: u64) {
    record(|s| {
        s.fallback_draws.fetch_add(draws, Ordering::Relaxed);
    });
}

/// A space was detected as unsampleable.
pub fn record_infeasible_space() {
    record(|s| {
        s.infeasible_spaces.fetch_add(1, Ordering::Relaxed);
    });
}

/// A search loop skipped or truncated planned work because no candidate
/// could be sampled (the consumer-side degradation the space-level counters
/// cannot attribute).
pub fn record_degraded_skip() {
    record(|s| {
        s.degraded_skips.fetch_add(1, Ordering::Relaxed);
    });
}

/// `n` per-layer feasibility certificates were consulted by the cross-space
/// pruner.
pub fn record_certificates(n: u64) {
    record(|s| {
        s.prune_certificates.fetch_add(n, Ordering::Relaxed);
    });
}

/// A hardware configuration was rejected before evaluation on a
/// provably-empty certificate.
pub fn record_prune_rejection() {
    record(|s| {
        s.prune_rejections.fetch_add(1, Ordering::Relaxed);
    });
}

/// A certificate-store lookup was served from the shared memo.
pub fn record_cert_hit() {
    record(|s| {
        s.cert_hits.fetch_add(1, Ordering::Relaxed);
    });
}

/// A certificate-store lookup missed and computed a new certificate.
pub fn record_cert_miss() {
    record(|s| {
        s.cert_misses.fetch_add(1, Ordering::Relaxed);
    });
}

/// A lattice-derived relaxation box was handed to round-BO; `shrink` is its
/// volume reduction vs the raw divisor box (>= 1, capped so the milli
/// accumulator cannot overflow).
pub fn record_lattice_box(shrink: f64) {
    let milli = (shrink.clamp(1.0, 1e12) * 1000.0) as u64;
    record(|s| {
        s.lattice_boxes.fetch_add(1, Ordering::Relaxed);
        s.lattice_box_shrink_milli.fetch_add(milli, Ordering::Relaxed);
    });
}

/// `n` certified-nonempty cells were built into a per-layer mapping table.
pub fn record_table_cells(n: u64) {
    record(|s| {
        s.table_cells.fetch_add(n, Ordering::Relaxed);
    });
}

/// An outer-loop hardware evaluation was served as a table lookup.
pub fn record_table_hit() {
    record(|s| {
        s.table_hits.fetch_add(1, Ordering::Relaxed);
    });
}

/// A finalist was re-searched exactly to bound the optimality gap.
pub fn record_gap_resolved() {
    record(|s| {
        s.gap_resolved.fetch_add(1, Ordering::Relaxed);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone_and_attributable() {
        // Tests run in parallel and the counters are process-global, so
        // assert on deltas (>=), never on absolute values.
        let before = snapshot();
        record_constructed();
        record_perturbation();
        record_perturbation_fallback();
        record_projection();
        record_projection_failure();
        record_fallback_sample(42);
        record_fallback_exhausted(8);
        record_infeasible_space();
        record_degraded_skip();
        record_certificates(3);
        record_prune_rejection();
        record_cert_hit();
        record_cert_miss();
        record_lattice_box(2.5);
        record_table_cells(4);
        record_table_hit();
        record_gap_resolved();
        let delta = snapshot().since(&before);
        assert!(delta.constructed >= 1);
        assert!(delta.perturbations >= 1);
        assert!(delta.perturbation_fallbacks >= 1);
        assert!(delta.projections >= 1);
        assert!(delta.projection_failures >= 1);
        assert!(delta.fallback_samples >= 1);
        assert!(delta.fallback_draws >= 50);
        assert!(delta.infeasible_spaces >= 1);
        assert!(delta.degraded_skips >= 1);
        assert!(delta.prune_certificates >= 3);
        assert!(delta.prune_rejections >= 1);
        assert!(delta.cert_hits >= 1);
        assert!(delta.cert_misses >= 1);
        assert!(delta.lattice_boxes >= 1);
        assert!(delta.lattice_box_shrink_milli >= 2500);
        assert!(delta.table_cells >= 4);
        assert!(delta.table_hits >= 1);
        assert!(delta.gap_resolved >= 1);
    }

    #[test]
    fn lattice_box_shrink_saturates_instead_of_overflowing() {
        let before = snapshot();
        // a pathological shrink factor must clamp, not wrap the accumulator
        record_lattice_box(f64::INFINITY);
        record_lattice_box(0.1); // sub-1 shrink is clamped up to the floor
        let delta = snapshot().since(&before);
        assert!(delta.lattice_boxes >= 2);
        assert!(delta.lattice_box_shrink_milli >= 1_000_000_000_000_000 + 1000);
    }

    #[test]
    fn since_saturates() {
        let a = FeasibilityStats { constructed: 5, ..FeasibilityStats::default() };
        let b = FeasibilityStats { constructed: 9, ..FeasibilityStats::default() };
        assert_eq!(b.since(&a).constructed, 4);
        assert_eq!(a.since(&b).constructed, 0);
    }

    #[test]
    fn scoped_recording_lands_in_the_sink_and_the_global_view() {
        let sink = Arc::new(Sink::default());
        let before = snapshot();
        with_scope(&sink, || {
            record_constructed();
            record_certificates(2);
        });
        record_prune_rejection(); // outside the scope: global only
        let scoped = sink.snapshot();
        assert_eq!(scoped.constructed, 1);
        assert_eq!(scoped.prune_certificates, 2);
        assert_eq!(scoped.prune_rejections, 0, "unscoped events must not leak into the sink");
        let delta = snapshot().since(&before);
        assert!(delta.constructed >= 1 && delta.prune_rejections >= 1);
    }
}
