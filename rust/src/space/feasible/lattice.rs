//! Divisor lattices: the admissible blocking values of each loop dimension.
//!
//! Every blocking factor of a mapping (S1-S6 of Fig. 9) must divide the
//! layer's extent along that dimension, and the per-level factors must
//! multiply out exactly — so the candidate values at every level form the
//! divisor lattice of the dimension, and the *remaining* extent after the
//! inner levels are fixed is itself a lattice element whose divisors are a
//! sublattice. The local level is additionally pinned by the hardware
//! dataflow (H11/H12): a FullAtPe filter axis forces `local = extent`, a
//! Streamed axis forces `local = 1`.

use crate::model::arch::DataflowOpt;
use crate::model::workload::{Dim, Layer};
use crate::space::factors::divisors;

/// The admissible-factor lattice of one loop dimension on one hardware
/// configuration.
#[derive(Clone, Debug)]
pub struct DimLattice {
    pub dim: Dim,
    /// Full extent of the dimension.
    pub size: u64,
    /// Divisors of `size`, ascending — the raw lattice.
    divisors: Vec<u64>,
    /// Local blocking factor forced by the dataflow (H11/H12), if pinned.
    pub pinned_local: Option<u64>,
}

impl DimLattice {
    pub fn new(dim: Dim, layer: &Layer, dataflow: Option<DataflowOpt>) -> Self {
        let size = layer.size(dim);
        let pinned_local = dataflow.map(|opt| match opt {
            DataflowOpt::FullAtPe => size,
            DataflowOpt::Streamed => 1,
        });
        DimLattice { dim, size, divisors: divisors(size), pinned_local }
    }

    /// The smallest local factor any valid mapping must carry: the pinned
    /// value on dataflow axes, 1 everywhere else.
    pub fn min_local(&self) -> u64 {
        self.pinned_local.unwrap_or(1)
    }

    /// Size of the raw lattice (number of divisors of the full extent) —
    /// the per-decision volume of the *unconstrained* relaxation box, which
    /// the lattice-box shrink factor is measured against.
    pub fn divisor_count(&self) -> usize {
        self.divisors.len()
    }

    /// Divisors of `rem` (`rem` must divide `size`), ascending. Because
    /// `rem | size`, this is a filter over the precomputed lattice — no
    /// re-factorization on the sampling path.
    pub fn divisors_of(&self, rem: u64) -> impl Iterator<Item = u64> + '_ {
        debug_assert!(rem >= 1 && self.size % rem == 0, "rem {rem} !| size {}", self.size);
        self.divisors.iter().copied().filter(move |d| rem % d == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> Layer {
        Layer::conv("t", 3, 3, 12, 8, 16, 32, 1)
    }

    #[test]
    fn lattice_matches_divisors() {
        let lat = DimLattice::new(Dim::P, &layer(), None);
        assert_eq!(lat.size, 12);
        assert_eq!(lat.divisors_of(12).collect::<Vec<_>>(), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(lat.min_local(), 1);
        assert_eq!(lat.divisor_count(), 6);
    }

    #[test]
    fn sublattice_of_remaining_extent() {
        let lat = DimLattice::new(Dim::C, &layer(), None);
        // after an inner factor of 4 is fixed, only divisors of 4 remain
        assert_eq!(lat.divisors_of(4).collect::<Vec<_>>(), vec![1, 2, 4]);
        assert_eq!(lat.divisors_of(1).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn dataflow_pins_local() {
        let full = DimLattice::new(Dim::R, &layer(), Some(DataflowOpt::FullAtPe));
        assert_eq!(full.pinned_local, Some(3));
        assert_eq!(full.min_local(), 3);
        let streamed = DimLattice::new(Dim::S, &layer(), Some(DataflowOpt::Streamed));
        assert_eq!(streamed.pinned_local, Some(1));
        assert_eq!(streamed.min_local(), 1);
    }
}
