//! The constraint-propagation pass: intersect the divisor lattices with the
//! hardware's capacity constraints to yield, level by level, the admissible
//! factor set of every dimension — and construct mappings that are valid
//! **by construction** instead of by rejection.
//!
//! # The minimal-completion invariant
//!
//! The pass walks the split levels inner-to-outer (local, spatial-X,
//! spatial-Y, GLB; DRAM absorbs the leftover and is unconstrained) and
//! maintains one invariant: *completing every still-unchosen factor with its
//! minimal value (the dataflow-pinned local on H11/H12 axes, 1 everywhere
//! else) yields a mapping that passes every constraint of
//! [`crate::model::validity::check_mapping`]*. A candidate factor is
//! admissible iff the invariant survives it, which is decided by evaluating
//! the real footprint/replication arithmetic of `model::nest` on the partial
//! state — no approximation. The minimal value itself is always admissible,
//! so once the pass starts it cannot dead-end, and the final state (where
//! "minimal completion" is the state itself) is valid outright.
//!
//! # Exactness of the start check
//!
//! [`Propagator::space_check`] classifies the space before any choice:
//!
//! * local-buffer overflow of the minimal tile is a *proof* of emptiness —
//!   every valid mapping's local tile dominates the minimal tile pointwise
//!   and the footprints are monotone ([`SpaceCheck::ProvablyEmpty`]);
//! * a GLB-witness failure of the minimal tile is **not** a proof: spreading
//!   spatial loops can lower bank replication faster than it grows the
//!   (halo-overlapped) footprints, so such spaces degrade to the rejection-
//!   sampling fallback instead ([`SpaceCheck::GlbTight`]). The same
//!   non-monotonicity is why a perturbation reset re-checks its start state.

use crate::model::arch::{HwConfig, Resources};
use crate::model::energy::effective_glb_capacity;
use crate::model::mapping::Split;
use crate::model::nest::footprint;
use crate::model::workload::{DataSpace, Dim, Layer, DATASPACES, DIMS};
use crate::space::feasible::lattice::DimLattice;

/// What the propagation start check concluded about a (layer, hardware)
/// mapping space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceCheck {
    /// The minimal completion is valid: construction always succeeds.
    Constructive,
    /// The minimal tile overflows a PE-local sub-buffer: *no* valid mapping
    /// exists (exact — footprints are monotone in the tile extents).
    ProvablyEmpty,
    /// Only the GLB witness fails at the minimal completion. Spatial
    /// spreading could still admit mappings (replication is not monotone),
    /// so callers fall back to cross-checked rejection sampling.
    GlbTight,
}

/// Which split level a constructive decision fills, inner to outer. Public
/// so consumers of [`crate::space::feasible::FeasibleSampler::construct_targeted`]
/// and the lattice-box ranges can name the decision they are targeting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Slot {
    Local,
    SpatialX,
    SpatialY,
    Glb,
}

/// The constructive decision slots in pass order (inner to outer). Also the
/// index order of the per-slot arrays returned by
/// [`crate::space::feasible::FeasibleSampler::lattice_ranges`].
pub const SLOTS: [Slot; 4] = [Slot::Local, Slot::SpatialX, Slot::SpatialY, Slot::Glb];

/// Partial split assignment during propagation. Unchosen entries sit at
/// their minimal value, so the struct *is* the minimal completion at every
/// point of the pass.
#[derive(Clone, Debug)]
pub(crate) struct Partial {
    local: [u64; 6],
    sx: [u64; 6],
    sy: [u64; 6],
    glb: [u64; 6],
}

impl Partial {
    fn minimal(lats: &[DimLattice; 6]) -> Self {
        Partial {
            local: std::array::from_fn(|i| lats[i].min_local()),
            sx: [1; 6],
            sy: [1; 6],
            glb: [1; 6],
        }
    }

    fn from_splits(splits: &[Split; 6]) -> Self {
        Partial {
            local: std::array::from_fn(|i| splits[i].local),
            sx: std::array::from_fn(|i| splits[i].spatial_x),
            sy: std::array::from_fn(|i| splits[i].spatial_y),
            glb: std::array::from_fn(|i| splits[i].glb),
        }
    }

    fn get(&self, i: usize, slot: Slot) -> u64 {
        match slot {
            Slot::Local => self.local[i],
            Slot::SpatialX => self.sx[i],
            Slot::SpatialY => self.sy[i],
            Slot::Glb => self.glb[i],
        }
    }

    fn set(&mut self, i: usize, slot: Slot, v: u64) {
        match slot {
            Slot::Local => self.local[i] = v,
            Slot::SpatialX => self.sx[i] = v,
            Slot::SpatialY => self.sy[i] = v,
            Slot::Glb => self.glb[i] = v,
        }
    }

    /// Tile resident in the GLB under the minimal completion of this state.
    fn glb_tile(&self) -> [u64; 6] {
        std::array::from_fn(|i| self.local[i] * self.sx[i] * self.sy[i] * self.glb[i])
    }

    fn sx_prod(&self) -> u64 {
        self.sx.iter().product()
    }

    fn sy_prod(&self) -> u64 {
        self.sy.iter().product()
    }
}

/// The propagation engine for one (layer, hardware, resources) triple.
pub(crate) struct Propagator<'a> {
    pub(crate) layer: &'a Layer,
    pub(crate) hw: &'a HwConfig,
    pub(crate) res: &'a Resources,
    pub(crate) lattices: &'a [DimLattice; 6],
}

impl Propagator<'_> {
    fn local_caps_ok(&self, p: &Partial) -> bool {
        let stride = self.layer.stride;
        footprint(DataSpace::Inputs, &p.local, stride) <= self.hw.lb_inputs
            && footprint(DataSpace::Weights, &p.local, stride) <= self.hw.lb_weights
            && footprint(DataSpace::Outputs, &p.local, stride) <= self.hw.lb_outputs
    }

    /// Bank replication of a dataspace under the partial spatial assignment
    /// (same arithmetic as `model::nest::replication`, evaluated on the
    /// partial state instead of a finished `Mapping`).
    fn replication(&self, p: &Partial, ds: DataSpace) -> f64 {
        let mut rel_x = 1u64;
        let mut rel_y = 1u64;
        for d in DIMS {
            if ds.relevant(d) {
                rel_x *= p.sx[d.index()];
                rel_y *= p.sy[d.index()];
            }
        }
        let rx = (self.hw.gb_mesh_x as f64 / rel_x.min(self.hw.gb_mesh_x) as f64).max(1.0);
        let ry = (self.hw.gb_mesh_y as f64 / rel_y.min(self.hw.gb_mesh_y) as f64).max(1.0);
        rx * ry
    }

    /// Exact GLB-capacity check of the minimal completion of `p`.
    fn glb_witness_ok(&self, p: &Partial) -> bool {
        let tile = p.glb_tile();
        let stride = self.layer.stride;
        let used: f64 = DATASPACES
            .iter()
            .map(|&ds| footprint(ds, &tile, stride) as f64 * self.replication(p, ds))
            .sum();
        used <= effective_glb_capacity(self.hw, self.res)
    }

    fn state_ok(&self, p: &Partial) -> bool {
        self.local_caps_ok(p)
            && p.sx_prod() <= self.hw.pe_mesh_x
            && p.sy_prod() <= self.hw.pe_mesh_y
            && self.glb_witness_ok(p)
    }

    /// Classify the space from its minimal completion (see module doc).
    pub(crate) fn space_check(&self) -> SpaceCheck {
        let p = Partial::minimal(self.lattices);
        if !self.local_caps_ok(&p) {
            return SpaceCheck::ProvablyEmpty;
        }
        if !self.glb_witness_ok(&p) {
            return SpaceCheck::GlbTight;
        }
        SpaceCheck::Constructive
    }

    /// Exact emptiness decision for a [`SpaceCheck::GlbTight`] space:
    /// exhaustively enumerate every spatial assignment (per-dim divisors,
    /// joint mesh fit) with all temporal factors at their minimum, and
    /// return the first state whose GLB witness holds.
    ///
    /// This is a *complete* decision procedure, not a heuristic: for any
    /// valid mapping `m` with factors `(loc, sx, sy, glb)`, the reduced
    /// state `(min_local, sx, sy, 1)` is in the enumeration, its GLB tile
    /// is dominated pointwise by `m`'s (footprints are monotone in the
    /// temporal factors) and its bank replication is *identical* (it
    /// depends only on the spatial factors) — so the reduced state passes
    /// whenever `m` does. Hence `None` proves the space empty, and a
    /// `Some(splits)` witness is itself a valid mapping (finished with DRAM
    /// absorbing the leftover). The enumeration is small by construction:
    /// spatial products are bounded by the PE mesh extents.
    pub(crate) fn glb_tight_witness(&self) -> Option<[Split; 6]> {
        let extents: [u64; 6] =
            std::array::from_fn(|i| self.lattices[i].size / self.lattices[i].min_local());
        for sx in spatial_assignments(self.lattices, &extents, self.hw.pe_mesh_x) {
            let rem: [u64; 6] = std::array::from_fn(|i| extents[i] / sx[i]);
            for sy in spatial_assignments(self.lattices, &rem, self.hw.pe_mesh_y) {
                let mut p = Partial::minimal(self.lattices);
                p.sx = sx;
                p.sy = sy;
                if self.state_ok(&p) {
                    return Some(self.finish(&p));
                }
            }
        }
        None
    }

    /// Admissible factor values for `(d, slot)` under the current partial
    /// state: divisors of the dimension's remaining extent that keep the
    /// minimal-completion invariant. Never empty while the invariant holds
    /// (the minimal value re-passes its own check).
    fn admissible(&self, p: &mut Partial, d: Dim, slot: Slot) -> Vec<u64> {
        let i = d.index();
        let lat = &self.lattices[i];
        let rem = match slot {
            Slot::Local => lat.size,
            Slot::SpatialX => lat.size / p.local[i],
            Slot::SpatialY => lat.size / (p.local[i] * p.sx[i]),
            Slot::Glb => lat.size / (p.local[i] * p.sx[i] * p.sy[i]),
        };
        let saved = p.get(i, slot);
        let mut adm = Vec::new();
        for v in lat.divisors_of(rem) {
            p.set(i, slot, v);
            let ok = match slot {
                Slot::Local => self.local_caps_ok(p) && self.glb_witness_ok(p),
                Slot::SpatialX => p.sx_prod() <= self.hw.pe_mesh_x && self.glb_witness_ok(p),
                Slot::SpatialY => p.sy_prod() <= self.hw.pe_mesh_y && self.glb_witness_ok(p),
                Slot::Glb => self.glb_witness_ok(p),
            };
            if ok {
                adm.push(v);
            }
        }
        p.set(i, slot, saved);
        adm
    }

    fn finish(&self, p: &Partial) -> [Split; 6] {
        std::array::from_fn(|i| {
            let inner = p.local[i] * p.sx[i] * p.sy[i] * p.glb[i];
            Split {
                dram: self.lattices[i].size / inner,
                glb: p.glb[i],
                spatial_x: p.sx[i],
                spatial_y: p.sy[i],
                local: p.local[i],
            }
        })
    }

    /// One full constructive pass: visit the dims of each level in the given
    /// order and let `choose` pick from every admissible set. Returns `None`
    /// only when the space is not [`SpaceCheck::Constructive`] — hot-path
    /// callers gate on a *cached* [`Propagator::space_check`] verdict
    /// instead of paying it per sample; a non-constructive space that slips
    /// through surfaces as an empty admissible set at the first decision
    /// (every candidate fails the same witness the start check evaluates).
    pub(crate) fn construct(
        &self,
        orders: &[[Dim; 6]; 4],
        mut choose: impl FnMut(Dim, Slot, &[u64]) -> u64,
    ) -> Option<[Split; 6]> {
        let mut p = Partial::minimal(self.lattices);
        for (li, slot) in SLOTS.into_iter().enumerate() {
            for &d in &orders[li] {
                let i = d.index();
                if slot == Slot::Local && self.lattices[i].pinned_local.is_some() {
                    continue; // forced by the dataflow; already in the state
                }
                let adm = self.admissible(&mut p, d, slot);
                if adm.is_empty() {
                    // non-constructive space (or a lost invariant): bail
                    return None;
                }
                let v = choose(d, slot, &adm);
                debug_assert!(adm.contains(&v), "chooser left the admissible set");
                p.set(i, slot, v);
            }
        }
        Some(self.finish(&p))
    }

    /// Re-derive one dimension of a *feasible* base split in place: reset it
    /// to its minimal values, verify the reset state is still valid (tile
    /// shrinkage can raise bank replication — see module doc), then re-run
    /// the per-level choices for that dimension alone. Returns `None` when
    /// the reset state fails, in which case the caller should fall back to
    /// an always-safe move.
    pub(crate) fn resplit(
        &self,
        base: &[Split; 6],
        d: Dim,
        mut choose: impl FnMut(Dim, Slot, &[u64]) -> u64,
    ) -> Option<[Split; 6]> {
        let mut p = Partial::from_splits(base);
        let i = d.index();
        p.local[i] = self.lattices[i].min_local();
        p.sx[i] = 1;
        p.sy[i] = 1;
        p.glb[i] = 1;
        if !self.state_ok(&p) {
            return None;
        }
        for slot in SLOTS {
            if slot == Slot::Local && self.lattices[i].pinned_local.is_some() {
                continue;
            }
            let adm = self.admissible(&mut p, d, slot);
            if adm.is_empty() {
                return None;
            }
            let v = choose(d, slot, &adm);
            p.set(i, slot, v);
        }
        Some(self.finish(&p))
    }
}

/// Every per-dimension spatial assignment whose factors divide the given
/// remaining extents and whose product fits `mesh`: the (small) search
/// space of [`Propagator::glb_tight_witness`]. Divisor iteration is
/// ascending, so a dimension's candidates are cut off at the first mesh
/// overflow.
fn spatial_assignments(
    lats: &[DimLattice; 6],
    rems: &[u64; 6],
    mesh: u64,
) -> Vec<[u64; 6]> {
    fn rec(
        lats: &[DimLattice; 6],
        rems: &[u64; 6],
        mesh: u64,
        i: usize,
        prod: u64,
        cur: &mut [u64; 6],
        out: &mut Vec<[u64; 6]>,
    ) {
        if i == cur.len() {
            out.push(*cur);
            return;
        }
        for v in lats[i].divisors_of(rems[i]) {
            if prod * v > mesh {
                break; // ascending: everything after overflows too
            }
            cur[i] = v;
            rec(lats, rems, mesh, i + 1, prod * v, cur, out);
        }
        cur[i] = 1;
    }
    let mut out = Vec::new();
    let mut cur = [1u64; 6];
    rec(lats, rems, mesh, 0, 1, &mut cur, &mut out);
    out
}

/// The admissible value closest to `target` in log space; ties go to the
/// smaller value (the sets are ascending). Used by the nearest-feasible
/// projection.
pub(crate) fn nearest_in_log(adm: &[u64], target: u64) -> u64 {
    nearest_ln(adm, (target.max(1) as f64).ln())
}

/// The admissible value whose natural log is closest to `target_ln`; ties go
/// to the smaller value. The continuous-target core behind
/// [`nearest_in_log`], used directly by the lattice-box decode (box
/// coordinates map to log-space positions, not integer factors).
pub(crate) fn nearest_ln(adm: &[u64], target_ln: f64) -> u64 {
    debug_assert!(!adm.is_empty());
    let lt = if target_ln.is_finite() { target_ln } else { 0.0 };
    let mut best = adm[0];
    let mut best_dist = f64::INFINITY;
    for &v in adm {
        let dist = ((v as f64).ln() - lt).abs();
        if dist + 1e-12 < best_dist {
            best = v;
            best_dist = dist;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch::DataflowOpt;
    use crate::model::mapping::Mapping;
    use crate::model::validity::check_mapping;
    use crate::util::rng::Rng;

    fn hw() -> HwConfig {
        HwConfig {
            pe_mesh_x: 14,
            pe_mesh_y: 12,
            lb_inputs: 12,
            lb_weights: 192,
            lb_outputs: 16,
            gb_instances: 2,
            gb_mesh_x: 2,
            gb_mesh_y: 1,
            gb_block: 4,
            gb_cluster: 2,
            df_filter_w: DataflowOpt::FullAtPe,
            df_filter_h: DataflowOpt::Streamed,
        }
    }

    fn layer() -> Layer {
        Layer::conv("t", 3, 3, 8, 8, 16, 32, 1)
    }

    fn lattices(layer: &Layer, hw: &HwConfig) -> [DimLattice; 6] {
        std::array::from_fn(|i| DimLattice::new(DIMS[i], layer, hw.dataflow_for(DIMS[i])))
    }

    #[test]
    fn constructed_splits_pass_the_full_validator() {
        let (l, h, res) = (layer(), hw(), Resources::eyeriss_168());
        let lats = lattices(&l, &h);
        let prop = Propagator { layer: &l, hw: &h, res: &res, lattices: &lats };
        assert_eq!(prop.space_check(), SpaceCheck::Constructive);
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..300 {
            let mut order = DIMS;
            let orders: [[Dim; 6]; 4] = std::array::from_fn(|_| {
                rng.shuffle(&mut order);
                order
            });
            let splits = prop
                .construct(&orders, |_, _, adm| *rng.choose(adm))
                .expect("constructive space");
            let m = Mapping { splits, order_local: DIMS, order_glb: DIMS, order_dram: DIMS };
            assert_eq!(check_mapping(&l, &h, &res, &m), Ok(()));
        }
    }

    #[test]
    fn construction_explores_beyond_the_minimal_mapping() {
        let (l, h, res) = (layer(), hw(), Resources::eyeriss_168());
        let lats = lattices(&l, &h);
        let prop = Propagator { layer: &l, hw: &h, res: &res, lattices: &lats };
        let mut rng = Rng::seed_from_u64(2);
        let mut distinct = std::collections::HashSet::new();
        let mut spatial_used = 0u64;
        for _ in 0..200 {
            let orders = [DIMS; 4];
            let splits = prop.construct(&orders, |_, _, adm| *rng.choose(adm)).unwrap();
            let spatial: u64 = splits.iter().map(|s| s.spatial_x * s.spatial_y).product();
            spatial_used = spatial_used.max(spatial);
            distinct.insert(splits);
        }
        assert!(distinct.len() > 50, "only {} distinct splits", distinct.len());
        assert!(spatial_used > 1, "sampler never used the PE array");
    }

    #[test]
    fn pinned_overflow_is_provably_empty() {
        // FullAtPe on both filter axes with an 8-word weight buffer: the
        // forced 3x3 local weight tile cannot fit — no mapping exists.
        let l = layer();
        let mut h = hw();
        h.df_filter_h = DataflowOpt::FullAtPe;
        h.lb_weights = 8;
        let lats = lattices(&l, &h);
        let prop =
            Propagator { layer: &l, hw: &h, res: &Resources::eyeriss_168(), lattices: &lats };
        assert_eq!(prop.space_check(), SpaceCheck::ProvablyEmpty);
        assert!(prop.construct(&[DIMS; 4], |_, _, adm| adm[0]).is_none());
    }

    #[test]
    fn resplit_preserves_validity_for_every_dim() {
        let (l, h, res) = (layer(), hw(), Resources::eyeriss_168());
        let lats = lattices(&l, &h);
        let prop = Propagator { layer: &l, hw: &h, res: &res, lattices: &lats };
        let mut rng = Rng::seed_from_u64(3);
        let base = prop.construct(&[DIMS; 4], |_, _, adm| *rng.choose(adm)).unwrap();
        for d in DIMS {
            for _ in 0..40 {
                let Some(splits) = prop.resplit(&base, d, |_, _, adm| *rng.choose(adm))
                else {
                    continue; // legal: the reset state may raise replication
                };
                let m = Mapping { splits, order_local: DIMS, order_glb: DIMS, order_dram: DIMS };
                assert_eq!(check_mapping(&l, &h, &res, &m), Ok(()), "resplit of {d:?}");
                // only dimension d moved
                for e in DIMS {
                    if e != d {
                        assert_eq!(splits[e.index()], base[e.index()]);
                    }
                }
            }
        }
    }

    #[test]
    fn nearest_in_log_prefers_geometric_closeness() {
        assert_eq!(nearest_in_log(&[1, 2, 4, 8, 16], 5), 4);
        assert_eq!(nearest_in_log(&[1, 2, 4, 8, 16], 6), 8);
        // exact hit
        assert_eq!(nearest_in_log(&[1, 3, 9], 3), 3);
        // ties go to the smaller value: 2 vs 8 around ln(4)
        assert_eq!(nearest_in_log(&[2, 8], 4), 2);
        assert_eq!(nearest_in_log(&[1], 1000), 1);
    }

    use crate::space::feasible::fixtures::tight_fixture;

    #[test]
    fn glb_tight_witness_is_exact_on_the_hand_computed_fixture() {
        // capacity 12: GLB-tight, but the sx[P]=2 spreading fits — the
        // exhaustive witness search must find it, and the finished splits
        // must pass the full validator
        let (l, h, res) = tight_fixture(12);
        let lats = lattices(&l, &h);
        let prop = Propagator { layer: &l, hw: &h, res: &res, lattices: &lats };
        assert_eq!(prop.space_check(), SpaceCheck::GlbTight);
        let splits = prop.glb_tight_witness().expect("capacity 12 admits sx[P]=2");
        assert_eq!(splits[Dim::P.index()].spatial_x, 2, "witness must spread P");
        let m = Mapping { splits, order_local: DIMS, order_glb: DIMS, order_dram: DIMS };
        assert_eq!(check_mapping(&l, &h, &res, &m), Ok(()));

        // capacity 11: GLB-tight and *provably empty* — every spatial
        // assignment (usages 14, 12, 16) overflows
        let (l, h, res) = tight_fixture(11);
        let lats = lattices(&l, &h);
        let prop = Propagator { layer: &l, hw: &h, res: &res, lattices: &lats };
        assert_eq!(prop.space_check(), SpaceCheck::GlbTight);
        assert!(prop.glb_tight_witness().is_none(), "capacity 11 must be proven empty");
    }

    #[test]
    fn glb_tight_witness_refuses_nothing_constructive() {
        // on a constructive space the all-minimal assignment passes, so the
        // witness search trivially succeeds — it may never claim emptiness
        let (l, h, res) = (layer(), hw(), Resources::eyeriss_168());
        let lats = lattices(&l, &h);
        let prop = Propagator { layer: &l, hw: &h, res: &res, lattices: &lats };
        assert_eq!(prop.space_check(), SpaceCheck::Constructive);
        assert!(prop.glb_tight_witness().is_some());
    }

    #[test]
    fn nearest_ln_takes_continuous_targets() {
        // between ln(4) and ln(8), closer to 8
        assert_eq!(nearest_ln(&[1, 2, 4, 8, 16], (7.0f64).ln()), 8);
        // an exact log hit
        assert_eq!(nearest_ln(&[1, 3, 9], (3.0f64).ln()), 3);
        // non-finite targets degrade to ln(1) = 0 instead of poisoning the
        // comparison (every distance would be NaN and the first value wins
        // anyway, but the clamp keeps the contract explicit)
        assert_eq!(nearest_ln(&[1, 2, 4], f64::NAN), 1);
        assert_eq!(nearest_ln(&[2, 4], f64::NEG_INFINITY), 2);
    }
}
