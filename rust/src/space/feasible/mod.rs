//! The feasibility engine: constraint-propagating, feasible-by-construction
//! candidate generation for the software mapping space.
//!
//! The paper's design space is so constrained that rejection sampling burns
//! ~99% of its raw draws (~22K draws per 150 feasible points, §5.1); every
//! search loop in this repo used to pay that on the hot path. This subsystem
//! replaces it: [`lattice`] enumerates the admissible blocking factorizations
//! of each layer dimension (the divisor lattices behind S1-S6 of Fig. 9),
//! [`propagate`] intersects those lattices with the hardware's capacity
//! constraints (H3-H5 local tiles, GLB with bank replication, the spatial
//! mesh fit) and the H11/H12 dataflow pinning to yield per-dimension
//! admissible tile sets, and [`FeasibleSampler`] turns the propagation pass
//! into three candidate generators:
//!
//! * [`FeasibleSampler::sample`] — a valid mapping in one draw, choosing
//!   uniformly from each admissible set (randomized dimension visit order);
//! * [`FeasibleSampler::perturb`] — a feasibility-preserving local move
//!   (re-derive one dimension's split, or swap two loops in one order);
//! * [`FeasibleSampler::project`] — a deterministic nearest-feasible
//!   projection (log-space nearest admissible factor per decision), used by
//!   round-BO to snap rounded box points onto feasible mappings.
//!
//! Rejection sampling survives only as a cross-checked fallback for the rare
//! [`SpaceCheck::GlbTight`] spaces where the propagation pass cannot start
//! (see `SwSpace::sample_valid`) — and even those are *resolved exactly* at
//! construction by the exhaustive spatial witness search
//! ([`FeasibleSampler::certified_empty`] / [`FeasibleSampler::glb_witness`]),
//! so emptiness is always a proof and never a burned draw budget. Every
//! path records its outcome in [`telemetry`], which `coordinator::metrics`
//! surfaces per run.

mod lattice;
mod propagate;
pub mod telemetry;

pub use lattice::DimLattice;
pub use propagate::{SpaceCheck, Slot, SLOTS};

use crate::model::arch::{HwConfig, Resources};
use crate::model::delta::MappingDelta;
use crate::model::mapping::{is_permutation, Level, Mapping, Split};
use crate::model::nest::footprint;
use crate::model::validity::check_mapping;
use crate::model::workload::{DataSpace, Dim, Layer, DIMS};
use crate::util::rng::Rng;
use propagate::{nearest_in_log, nearest_ln, Propagator};

/// Inclusive bounds (and cardinality) of the lattice-admissible factors of
/// one (dim, slot) decision under the *monotone* constraints alone — the
/// divisor lattice, the H11/H12 local pinning, the PE-local capacities for
/// the local slot and the mesh extents for the spatial slots. Because only
/// monotone constraints are applied, **every feasible mapping's factor at
/// that decision lies inside the range** (the containment property the
/// lattice-derived relaxation box is built on); the GLB witness is
/// deliberately excluded — bank replication is not monotone in the tile
/// extents, so it can never be used to shrink a containment box.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FactorRange {
    /// Smallest admissible factor (the pinned value on dataflow axes).
    pub min: u64,
    /// Largest admissible factor.
    pub max: u64,
    /// Number of admissible lattice values; 0 only when even the minimal
    /// factor violates a monotone constraint (the space is provably empty).
    pub count: usize,
}

impl FactorRange {
    pub fn contains(&self, v: u64) -> bool {
        (self.min..=self.max).contains(&v)
    }

    pub fn ln_min(&self) -> f64 {
        (self.min.max(1) as f64).ln()
    }

    pub fn ln_max(&self) -> f64 {
        (self.max.max(1) as f64).ln()
    }
}

/// Feasible-by-construction candidate generator for one (layer, hardware,
/// resources) triple. Construction is cheap (one divisor factorization per
/// dimension, plus — only on the rare GLB-tight spaces — the exhaustive
/// spatial witness search that makes their emptiness certificate exact);
/// clones share nothing and are cheap too.
#[derive(Clone, Debug)]
pub struct FeasibleSampler {
    layer: Layer,
    hw: HwConfig,
    resources: Resources,
    lattices: [DimLattice; 6],
    check: SpaceCheck,
    /// Exact resolution of a [`SpaceCheck::GlbTight`] start check: a
    /// feasibility witness if one exists (`None` on the other checks too).
    tight_witness: Option<[Split; 6]>,
    /// Exact emptiness: `ProvablyEmpty`, or GLB-tight with no witness.
    empty_proof: bool,
}

impl FeasibleSampler {
    pub fn new(layer: Layer, hw: HwConfig, resources: Resources) -> Self {
        let lattices: [DimLattice; 6] =
            std::array::from_fn(|i| DimLattice::new(DIMS[i], &layer, hw.dataflow_for(DIMS[i])));
        let prop = Propagator {
            layer: &layer,
            hw: &hw,
            res: &resources,
            lattices: &lattices,
        };
        let check = prop.space_check();
        // Resolve GLB-tight spaces exactly up front: the exhaustive spatial
        // witness search either proves emptiness (no rejection budget is
        // ever spent on the space again) or yields a valid fallback mapping.
        let (tight_witness, empty_proof) = match check {
            SpaceCheck::Constructive => (None, false),
            SpaceCheck::ProvablyEmpty => (None, true),
            SpaceCheck::GlbTight => {
                let w = prop.glb_tight_witness();
                let empty = w.is_none();
                (w, empty)
            }
        };
        FeasibleSampler { layer, hw, resources, lattices, check, tight_witness, empty_proof }
    }

    /// What the propagation start check concluded about this space (cached
    /// at construction; the inputs are immutable).
    pub fn check(&self) -> SpaceCheck {
        self.check
    }

    /// Exact emptiness certificate: `true` iff *no* valid mapping exists —
    /// either the pinned minimal tile overflows a PE-local buffer
    /// ([`SpaceCheck::ProvablyEmpty`]), or the space is
    /// [`SpaceCheck::GlbTight`] and the exhaustive spatial witness search
    /// found nothing. Both directions are proofs (property-tested against
    /// rejection sampling), so consumers may skip their rejection budget on
    /// a `true` and the cross-space pruner may reject the hardware point.
    pub fn certified_empty(&self) -> bool {
        self.empty_proof
    }

    /// The GLB-tight feasibility witness (canonical loop orders): a valid
    /// mapping proving a [`SpaceCheck::GlbTight`] space non-empty. `None`
    /// on every other check and on proven-empty tight spaces.
    pub fn glb_witness(&self) -> Option<Mapping> {
        self.tight_witness.map(|splits| Mapping {
            splits,
            order_local: DIMS,
            order_glb: DIMS,
            order_dram: DIMS,
        })
    }

    fn propagator(&self) -> Propagator<'_> {
        Propagator {
            layer: &self.layer,
            hw: &self.hw,
            res: &self.resources,
            lattices: &self.lattices,
        }
    }

    /// One valid-by-construction mapping: uniform choice from each
    /// admissible factor set under a randomized dimension visit order, plus
    /// uniformly shuffled loop orders. `None` iff the space is not
    /// [`SpaceCheck::Constructive`] (fall back to rejection sampling then).
    pub fn sample(&self, rng: &mut Rng) -> Option<Mapping> {
        if self.check != SpaceCheck::Constructive {
            return None;
        }
        let mut order = DIMS;
        let orders: [[Dim; 6]; 4] = std::array::from_fn(|_| {
            rng.shuffle(&mut order);
            order
        });
        let splits = self.propagator().construct(&orders, |_, _, adm| *rng.choose(adm))?;
        let mut order_local = DIMS;
        let mut order_glb = DIMS;
        let mut order_dram = DIMS;
        rng.shuffle(&mut order_local);
        rng.shuffle(&mut order_glb);
        rng.shuffle(&mut order_dram);
        telemetry::record_constructed();
        Some(Mapping { splits, order_local, order_glb, order_dram })
    }

    /// Feasibility-preserving local move from a *feasible* base: with
    /// probability 0.6 re-derive one dimension's split through the
    /// propagation pass (uniform over its admissible sets, every other
    /// dimension held fixed), cross-checked against the full validator;
    /// the other 40% of moves deliberately swap two loops in one order,
    /// which never affects validity. Exactly one counter is recorded per
    /// call, and `perturbation_fallbacks` counts only *degradations* —
    /// the reset state failing its re-check (tile shrinkage can raise bank
    /// replication), a failed cross-check (invalid base), or a
    /// non-constructive space — never the deliberate order-swap arm, so a
    /// resplit-kernel regression is visible above zero, not hidden in the
    /// 40% baseline.
    pub fn perturb(&self, rng: &mut Rng, base: &Mapping) -> Mapping {
        self.perturb_described(rng, base).0
    }

    /// [`FeasibleSampler::perturb`] plus an exact [`MappingDelta`] describing
    /// the move relative to `base` — the handshake that lets perturbation
    /// consumers route the candidate through
    /// [`crate::model::delta::DeltaEvaluator::evaluate_delta`] without
    /// re-diffing. Draws the *same* RNG stream as `perturb` (which is a thin
    /// wrapper), so switching call sites between the two is trace-neutral.
    pub fn perturb_described(&self, rng: &mut Rng, base: &Mapping) -> (Mapping, MappingDelta) {
        if self.check != SpaceCheck::Constructive {
            // no propagation on this space: order swaps are all we have
            telemetry::record_perturbation_fallback();
        } else if rng.chance(0.6) {
            let d = *rng.choose(&DIMS);
            if let Some(splits) =
                self.propagator().resplit(&base.splits, d, |_, _, adm| *rng.choose(adm))
            {
                let m = Mapping {
                    splits,
                    order_local: base.order_local,
                    order_glb: base.order_glb,
                    order_dram: base.order_dram,
                };
                // valid-by-construction for a feasible base; the cheap
                // cross-check catches caller-contract violations
                if check_mapping(&self.layer, &self.hw, &self.resources, &m).is_ok() {
                    telemetry::record_perturbation();
                    // the resplit may land back on the base's factors
                    let delta = if m.splits == base.splits {
                        MappingDelta::Identity
                    } else {
                        MappingDelta::Resplit(d)
                    };
                    return (m, delta);
                }
            }
            // degradation: the resplit was refused or failed its check
            telemetry::record_perturbation_fallback();
        } else {
            // the deliberate order-swap arm of the move mixture
            telemetry::record_perturbation();
        }
        let mut m = base.clone();
        let (order, level) = match rng.below(3) {
            0 => (&mut m.order_local, Level::Local),
            1 => (&mut m.order_glb, Level::Glb),
            _ => (&mut m.order_dram, Level::Dram),
        };
        let i = rng.below(6);
        let j = rng.below(6);
        order.swap(i, j);
        let delta =
            if i == j { MappingDelta::Identity } else { MappingDelta::OrderSwap(level) };
        (m, delta)
    }

    /// Deterministic nearest-feasible projection: re-run the propagation
    /// pass in canonical dimension order, picking from each admissible set
    /// the factor closest (in log space) to the target's factor at that
    /// level; loop orders carry over (sanitized to permutations). The output
    /// is feasible whenever the space is [`SpaceCheck::Constructive`] —
    /// this is how round-BO snaps relax-and-round points onto the feasible
    /// set instead of recording penalty observations.
    pub fn project(&self, target: &Mapping) -> Option<Mapping> {
        if self.check != SpaceCheck::Constructive {
            telemetry::record_projection_failure();
            return None;
        }
        let splits = self.propagator().construct(&[DIMS; 4], |d, slot, adm| {
            let s = target.split(d);
            let want = match slot {
                Slot::Local => s.local,
                Slot::SpatialX => s.spatial_x,
                Slot::SpatialY => s.spatial_y,
                Slot::Glb => s.glb,
            };
            nearest_in_log(adm, want)
        });
        let Some(splits) = splits else {
            telemetry::record_projection_failure();
            return None;
        };
        let keep = |o: &[Dim; 6]| if is_permutation(o) { *o } else { DIMS };
        telemetry::record_projection();
        Some(Mapping {
            splits,
            order_local: keep(&target.order_local),
            order_glb: keep(&target.order_glb),
            order_dram: keep(&target.order_dram),
        })
    }

    /// Number of constructive decisions a sample makes (for space sizing /
    /// diagnostics): dims x unpinned levels.
    pub fn decision_count(&self) -> usize {
        let pinned = self.lattices.iter().filter(|l| l.pinned_local.is_some()).count();
        DIMS.len() * SLOTS.len() - pinned
    }

    /// Whether a local tile with factor `v` on dimension `d` and the
    /// minimal (pinned / 1) factor everywhere else fits the PE-local
    /// sub-buffers. Footprints are monotone in the tile extents and every
    /// valid mapping's local tile dominates this one pointwise, so a `false`
    /// here excludes `v` from *every* feasible mapping — the exactness
    /// argument behind the local row of [`FeasibleSampler::lattice_sets`].
    fn local_fits(&self, d: Dim, v: u64) -> bool {
        let mut tile: [u64; 6] = std::array::from_fn(|i| self.lattices[i].min_local());
        tile[d.index()] = v;
        let stride = self.layer.stride;
        footprint(DataSpace::Inputs, &tile, stride) <= self.hw.lb_inputs
            && footprint(DataSpace::Weights, &tile, stride) <= self.hw.lb_weights
            && footprint(DataSpace::Outputs, &tile, stride) <= self.hw.lb_outputs
    }

    /// The lattice-admissible value sets per (slot, dim) under the monotone
    /// constraints alone (see [`FactorRange`] for the containment argument).
    /// Outer index follows [`SLOTS`], inner index is `Dim::index()`.
    pub fn lattice_sets(&self) -> [[Vec<u64>; 6]; 4] {
        // transposed construction keeps the per-slot logic together; the
        // public accessors below re-slice per dim
        std::array::from_fn(|si| {
            let slot = SLOTS[si];
            std::array::from_fn(|i| {
                let d = DIMS[i];
                let lat = &self.lattices[i];
                match slot {
                    Slot::Local => match lat.pinned_local {
                        Some(p) if self.local_fits(d, p) => vec![p],
                        Some(_) => Vec::new(), // provably empty space
                        None => {
                            lat.divisors_of(lat.size).filter(|&v| self.local_fits(d, v)).collect()
                        }
                    },
                    Slot::SpatialX => {
                        lat.divisors_of(lat.size).filter(|&v| v <= self.hw.pe_mesh_x).collect()
                    }
                    Slot::SpatialY => {
                        lat.divisors_of(lat.size).filter(|&v| v <= self.hw.pe_mesh_y).collect()
                    }
                    Slot::Glb => lat.divisors_of(lat.size).collect(),
                }
            })
        })
    }

    /// The lattice-box ranges per (dim, slot): min/max/count of
    /// [`FeasibleSampler::lattice_sets`], outer index `Dim::index()`, inner
    /// index following [`SLOTS`]. This is the relaxation box round-BO's
    /// `lattice_box` mode maps its coordinates onto, and the per-dimension
    /// admissible report `PrunedHwSpace` unions across target layers.
    pub fn lattice_ranges(&self) -> [[FactorRange; 4]; 6] {
        let sets = self.lattice_sets();
        std::array::from_fn(|i| {
            std::array::from_fn(|si| {
                let s = &sets[si][i];
                match (s.first(), s.last()) {
                    (Some(&min), Some(&max)) => FactorRange { min, max, count: s.len() },
                    // empty (provably-empty space): collapse onto the
                    // minimal factor so log-span arithmetic stays finite
                    _ => FactorRange {
                        min: self.lattices[i].min_local(),
                        max: self.lattices[i].min_local(),
                        count: 0,
                    },
                }
            })
        })
    }

    /// Volume reduction of the lattice box vs the raw divisor box: the
    /// product over all (dim, slot) decisions of
    /// `|divisor lattice| / |admissible set|`. Always >= 1; reported through
    /// [`telemetry::record_lattice_box`] when round-BO derives its box.
    pub fn box_shrink_factor(&self) -> f64 {
        let ranges = self.lattice_ranges();
        let mut shrink = 1.0f64;
        for i in 0..DIMS.len() {
            let raw = self.lattices[i].divisor_count() as f64;
            for r in &ranges[i] {
                if r.count > 0 {
                    shrink *= raw / r.count as f64;
                }
            }
        }
        shrink.max(1.0)
    }

    /// Deterministic construction steering each decision toward a
    /// continuous log-space target: at every (dim, slot) the admissible
    /// factor nearest (in ln) to `target_ln(dim, slot)` is chosen, in
    /// canonical dimension order. This is how the lattice-derived relaxation
    /// box decodes round-BO points — the targets come from box coordinates
    /// mapped onto [`FeasibleSampler::lattice_ranges`] — so the decoded
    /// mapping is feasible by construction. `None` iff the space is not
    /// [`SpaceCheck::Constructive`].
    pub fn construct_targeted(
        &self,
        mut target_ln: impl FnMut(Dim, Slot) -> f64,
    ) -> Option<[Split; 6]> {
        if self.check != SpaceCheck::Constructive {
            return None;
        }
        self.propagator().construct(&[DIMS; 4], |d, slot, adm| nearest_ln(adm, target_ln(d, slot)))
    }
}

/// Test fixtures shared across the unit suites of the space layer (the
/// integration suites keep an equivalent copy in `rust/tests/common/` —
/// `#[cfg(test)]` items are not linked into the library integration tests
/// build against).
#[cfg(test)]
pub(crate) mod fixtures {
    use crate::model::arch::{DataflowOpt, HwConfig, Resources};
    use crate::model::workload::Layer;

    /// The hand-computed GLB-tight fixture: an r=3 filter pinned FullAtPe,
    /// one spreadable P dimension (P=4 on a 4x1 mesh), two GLB banks. GLB
    /// usage by spatial split of P with all temporal factors minimal is
    /// {sx=1: 14, sx=2: 12, sx=4: 16} words (the sliding-window halo makes
    /// input growth sublinear while bank replication halves), so capacity
    /// 12 is tight-but-feasible (witness at sx[P]=2) and capacity 11 is
    /// tight-and-provably-empty.
    pub(crate) fn tight_fixture(glb_entries: u64) -> (Layer, HwConfig, Resources) {
        let layer = Layer::conv("tight", 3, 1, 4, 1, 1, 1, 1);
        let hw = HwConfig {
            pe_mesh_x: 4,
            pe_mesh_y: 1,
            lb_inputs: 3,
            lb_weights: 3,
            lb_outputs: 1,
            gb_instances: 2,
            gb_mesh_x: 2,
            gb_mesh_y: 1,
            gb_block: 1,
            gb_cluster: 1,
            df_filter_w: DataflowOpt::FullAtPe,
            df_filter_h: DataflowOpt::Streamed,
        };
        let res = Resources {
            num_pes: 4,
            local_buffer_entries: 7,
            global_buffer_entries: glb_entries,
            dram_words_per_cycle: 4.0,
            gb_words_per_cycle_per_instance: 2.0,
        };
        (layer, hw, res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::validity::check_mapping;
    use crate::workloads::eyeriss::{eyeriss_hw, eyeriss_resources};
    use crate::workloads::specs::layer_by_name;

    fn sampler(layer: &str) -> FeasibleSampler {
        FeasibleSampler::new(
            layer_by_name(layer).unwrap(),
            eyeriss_hw(168),
            eyeriss_resources(168),
        )
    }

    #[test]
    fn samples_are_valid_and_diverse() {
        let fs = sampler("ResNet-K2");
        assert_eq!(fs.check(), SpaceCheck::Constructive);
        let mut rng = Rng::seed_from_u64(1);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..200 {
            let m = fs.sample(&mut rng).expect("constructive space");
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &m), Ok(()));
            distinct.insert(m);
        }
        assert!(distinct.len() > 150, "only {} distinct mappings", distinct.len());
    }

    #[test]
    fn perturb_stays_feasible_and_moves() {
        let fs = sampler("DQN-K2");
        let mut rng = Rng::seed_from_u64(2);
        let base = fs.sample(&mut rng).unwrap();
        let mut moved = 0;
        for _ in 0..200 {
            let p = fs.perturb(&mut rng, &base);
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &p), Ok(()));
            if p != base {
                moved += 1;
            }
        }
        assert!(moved > 100, "perturb moved only {moved}/200 times");
    }

    #[test]
    fn perturb_described_deltas_are_exact_and_stream_neutral() {
        let fs = sampler("DQN-K2");
        let mut rng = Rng::seed_from_u64(2);
        let base = fs.sample(&mut rng).unwrap();
        for _ in 0..200 {
            let (m, delta) = fs.perturb_described(&mut rng, &base);
            // the reported delta is exactly what diffing reconstructs
            assert_eq!(MappingDelta::diff(&base, &m), Some(delta), "{delta:?}");
        }
        // the thin wrapper draws the identical stream
        let mut r1 = Rng::seed_from_u64(9);
        let mut r2 = Rng::seed_from_u64(9);
        for _ in 0..100 {
            assert_eq!(fs.perturb(&mut r1, &base), fs.perturb_described(&mut r2, &base).0);
        }
    }

    #[test]
    fn projection_is_deterministic_and_feasible() {
        let fs = sampler("DQN-K1");
        let mut rng = Rng::seed_from_u64(3);
        // a raw (usually invalid) draw from the unpropagated parameterization
        let sp = crate::space::sw_space::SwSpace::new(
            fs.layer.clone(),
            fs.hw.clone(),
            fs.resources.clone(),
        );
        for _ in 0..50 {
            let raw = sp.sample_raw(&mut rng);
            let a = fs.project(&raw).expect("constructive space");
            let b = fs.project(&raw).expect("constructive space");
            assert_eq!(a, b, "projection must be deterministic");
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &a), Ok(()));
            // loop orders carry over untouched
            assert_eq!(a.order_glb, raw.order_glb);
        }
    }

    #[test]
    fn projection_fixes_a_feasible_point_almost_in_place() {
        let fs = sampler("DQN-K2");
        let mut rng = Rng::seed_from_u64(4);
        let m = fs.sample(&mut rng).unwrap();
        let p = fs.project(&m).unwrap();
        assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &p), Ok(()));
        // the projection of an already-feasible mapping keeps its orders and
        // stays feasible; the splits may differ only through the witness's
        // conservative visit order, so at minimum the pinned axes agree
        assert_eq!(p.split(Dim::R).local, m.split(Dim::R).local);
        assert_eq!(p.order_dram, m.order_dram);
    }

    #[test]
    fn empty_space_is_detected_not_sampled() {
        // Shrink the weight buffer below the pinned 8x8 DQN-K1 filter tile.
        let mut hw = eyeriss_hw(168);
        hw.df_filter_w = crate::model::arch::DataflowOpt::FullAtPe;
        hw.lb_weights = 4;
        let fs = FeasibleSampler::new(
            layer_by_name("DQN-K1").unwrap(),
            hw,
            eyeriss_resources(168),
        );
        assert_eq!(fs.check(), SpaceCheck::ProvablyEmpty);
        let mut rng = Rng::seed_from_u64(5);
        assert!(fs.sample(&mut rng).is_none());
        assert!(fs.project(&Mapping::trivial(&fs.layer)).is_none());
    }

    #[test]
    fn decision_count_reflects_pinning() {
        let fs = sampler("DQN-K2");
        // 6 dims x 4 slots minus the two dataflow-pinned local decisions
        assert_eq!(fs.decision_count(), 22);
    }

    #[test]
    fn lattice_ranges_contain_every_sampled_mapping() {
        // The containment property the lattice-derived relaxation box rests
        // on: monotone-only filtering can never exclude a feasible factor.
        for name in ["DQN-K1", "DQN-K2", "ResNet-K2"] {
            let fs = sampler(name);
            let ranges = fs.lattice_ranges();
            let mut rng = Rng::seed_from_u64(11);
            for _ in 0..50 {
                let m = fs.sample(&mut rng).expect("constructive space");
                for (i, d) in DIMS.iter().enumerate() {
                    let s = m.split(*d);
                    for (si, slot) in SLOTS.iter().enumerate() {
                        let v = match slot {
                            Slot::Local => s.local,
                            Slot::SpatialX => s.spatial_x,
                            Slot::SpatialY => s.spatial_y,
                            Slot::Glb => s.glb,
                        };
                        assert!(
                            ranges[i][si].contains(v),
                            "{name}: {d:?}/{slot:?} factor {v} outside {:?}",
                            ranges[i][si]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lattice_ranges_respect_pinning_and_mesh() {
        let fs = sampler("DQN-K2"); // Eyeriss: R FullAtPe (r=4), S Streamed
        let ranges = fs.lattice_ranges();
        let local = |d: Dim| ranges[d.index()][0];
        assert_eq!(local(Dim::R), FactorRange { min: 4, max: 4, count: 1 });
        assert_eq!(local(Dim::S), FactorRange { min: 1, max: 1, count: 1 });
        // spatial slots are bounded by the mesh extents (14 x 12)
        for d in DIMS {
            assert!(ranges[d.index()][1].max <= 14, "{d:?} spatial-X over mesh");
            assert!(ranges[d.index()][2].max <= 12, "{d:?} spatial-Y over mesh");
        }
        // the GLB slot keeps the full divisor lattice (replication is not
        // monotone, so nothing may be cut there)
        assert_eq!(ranges[Dim::K.index()][3].max, fs.layer.k);
    }

    #[test]
    fn box_shrink_factor_is_at_least_one_and_counts_real_cuts() {
        let fs = sampler("DQN-K1");
        let shrink = fs.box_shrink_factor();
        assert!(shrink >= 1.0);
        // DQN-K1 on the 14x12 mesh: P = Q = 20 has divisors {1,2,4,5,10,20}
        // and 20 > 14 cuts at least one spatial value, so the box must
        // actually shrink
        assert!(shrink > 1.0, "expected a real cut, got {shrink}");
    }

    #[test]
    fn construct_targeted_is_deterministic_feasible_and_steerable() {
        let fs = sampler("ResNet-K2");
        let lo = fs.construct_targeted(|_, _| 0.0).expect("constructive");
        let lo2 = fs.construct_targeted(|_, _| 0.0).expect("constructive");
        assert_eq!(lo, lo2, "targeted construction must be deterministic");
        let hi = fs.construct_targeted(|d, slot| {
            let r = fs.lattice_ranges()[d.index()][SLOTS.iter().position(|s| *s == slot).unwrap()];
            r.ln_max()
        })
        .expect("constructive");
        for splits in [&lo, &hi] {
            let m = Mapping {
                splits: *splits,
                order_local: DIMS,
                order_glb: DIMS,
                order_dram: DIMS,
            };
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &m), Ok(()));
        }
        // steering toward the top of every range must move some factor off
        // the all-minimal construction
        assert_ne!(lo, hi, "targets must steer the construction");
    }

    /// The shared hand-computed GLB-tight fixture (see [`super::fixtures`]):
    /// capacity 12 admits exactly the sx[P]=2 spreading, capacity 11
    /// admits nothing.
    fn tight_sampler(glb_entries: u64) -> FeasibleSampler {
        let (layer, hw, res) = super::fixtures::tight_fixture(glb_entries);
        FeasibleSampler::new(layer, hw, res)
    }

    #[test]
    fn glb_tight_spaces_carry_exact_certificates() {
        // tight but feasible: not certified empty, witness validates
        let fs = tight_sampler(12);
        assert_eq!(fs.check(), SpaceCheck::GlbTight);
        assert!(!fs.certified_empty());
        let w = fs.glb_witness().expect("non-empty tight space must carry a witness");
        assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &w), Ok(()));
        // tight and proven empty: certificate flips, no witness
        let fs = tight_sampler(11);
        assert_eq!(fs.check(), SpaceCheck::GlbTight);
        assert!(fs.certified_empty());
        assert!(fs.glb_witness().is_none());
        // and the constructive / pinned-empty checks keep their certificates
        assert!(!sampler("DQN-K2").certified_empty());
    }

    #[test]
    fn construct_targeted_refuses_non_constructive_spaces() {
        let mut hw = eyeriss_hw(168);
        hw.df_filter_w = crate::model::arch::DataflowOpt::FullAtPe;
        hw.lb_weights = 4;
        let fs = FeasibleSampler::new(
            layer_by_name("DQN-K1").unwrap(),
            hw,
            eyeriss_resources(168),
        );
        assert_eq!(fs.check(), SpaceCheck::ProvablyEmpty);
        assert!(fs.construct_targeted(|_, _| 0.0).is_none());
        // and the collapsed ranges advertise the emptiness via count = 0
        let ranges = fs.lattice_ranges();
        assert!(ranges.iter().any(|per_dim| per_dim.iter().any(|r| r.count == 0)));
    }
}
