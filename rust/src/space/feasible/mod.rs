//! The feasibility engine: constraint-propagating, feasible-by-construction
//! candidate generation for the software mapping space.
//!
//! The paper's design space is so constrained that rejection sampling burns
//! ~99% of its raw draws (~22K draws per 150 feasible points, §5.1); every
//! search loop in this repo used to pay that on the hot path. This subsystem
//! replaces it: [`lattice`] enumerates the admissible blocking factorizations
//! of each layer dimension (the divisor lattices behind S1-S6 of Fig. 9),
//! [`propagate`] intersects those lattices with the hardware's capacity
//! constraints (H3-H5 local tiles, GLB with bank replication, the spatial
//! mesh fit) and the H11/H12 dataflow pinning to yield per-dimension
//! admissible tile sets, and [`FeasibleSampler`] turns the propagation pass
//! into three candidate generators:
//!
//! * [`FeasibleSampler::sample`] — a valid mapping in one draw, choosing
//!   uniformly from each admissible set (randomized dimension visit order);
//! * [`FeasibleSampler::perturb`] — a feasibility-preserving local move
//!   (re-derive one dimension's split, or swap two loops in one order);
//! * [`FeasibleSampler::project`] — a deterministic nearest-feasible
//!   projection (log-space nearest admissible factor per decision), used by
//!   round-BO to snap rounded box points onto feasible mappings.
//!
//! Rejection sampling survives only as a cross-checked fallback for the rare
//! [`SpaceCheck::GlbTight`] spaces where the propagation pass cannot start
//! (see `SwSpace::sample_valid`); every path records its outcome in
//! [`telemetry`], which `coordinator::metrics` surfaces per run.
#![deny(clippy::style)]

mod lattice;
mod propagate;
pub mod telemetry;

pub use lattice::DimLattice;
pub use propagate::SpaceCheck;

use crate::model::arch::{HwConfig, Resources};
use crate::model::mapping::{is_permutation, Mapping};
use crate::model::validity::check_mapping;
use crate::model::workload::{Dim, Layer, DIMS};
use crate::util::rng::Rng;
use propagate::{nearest_in_log, Propagator, Slot, SLOTS};

/// Feasible-by-construction candidate generator for one (layer, hardware,
/// resources) triple. Construction is cheap (one divisor factorization per
/// dimension); clones share nothing and are cheap too.
#[derive(Clone, Debug)]
pub struct FeasibleSampler {
    layer: Layer,
    hw: HwConfig,
    resources: Resources,
    lattices: [DimLattice; 6],
    check: SpaceCheck,
}

impl FeasibleSampler {
    pub fn new(layer: Layer, hw: HwConfig, resources: Resources) -> Self {
        let lattices: [DimLattice; 6] =
            std::array::from_fn(|i| DimLattice::new(DIMS[i], &layer, hw.dataflow_for(DIMS[i])));
        let check = Propagator {
            layer: &layer,
            hw: &hw,
            res: &resources,
            lattices: &lattices,
        }
        .space_check();
        FeasibleSampler { layer, hw, resources, lattices, check }
    }

    /// What the propagation start check concluded about this space (cached
    /// at construction; the inputs are immutable).
    pub fn check(&self) -> SpaceCheck {
        self.check
    }

    fn propagator(&self) -> Propagator<'_> {
        Propagator {
            layer: &self.layer,
            hw: &self.hw,
            res: &self.resources,
            lattices: &self.lattices,
        }
    }

    /// One valid-by-construction mapping: uniform choice from each
    /// admissible factor set under a randomized dimension visit order, plus
    /// uniformly shuffled loop orders. `None` iff the space is not
    /// [`SpaceCheck::Constructive`] (fall back to rejection sampling then).
    pub fn sample(&self, rng: &mut Rng) -> Option<Mapping> {
        if self.check != SpaceCheck::Constructive {
            return None;
        }
        let mut order = DIMS;
        let orders: [[Dim; 6]; 4] = std::array::from_fn(|_| {
            rng.shuffle(&mut order);
            order
        });
        let splits = self.propagator().construct(&orders, |_, _, adm| *rng.choose(adm))?;
        let mut order_local = DIMS;
        let mut order_glb = DIMS;
        let mut order_dram = DIMS;
        rng.shuffle(&mut order_local);
        rng.shuffle(&mut order_glb);
        rng.shuffle(&mut order_dram);
        telemetry::record_constructed();
        Some(Mapping { splits, order_local, order_glb, order_dram })
    }

    /// Feasibility-preserving local move from a *feasible* base: with
    /// probability 0.6 re-derive one dimension's split through the
    /// propagation pass (uniform over its admissible sets, every other
    /// dimension held fixed), cross-checked against the full validator;
    /// the other 40% of moves deliberately swap two loops in one order,
    /// which never affects validity. Exactly one counter is recorded per
    /// call, and `perturbation_fallbacks` counts only *degradations* —
    /// the reset state failing its re-check (tile shrinkage can raise bank
    /// replication), a failed cross-check (invalid base), or a
    /// non-constructive space — never the deliberate order-swap arm, so a
    /// resplit-kernel regression is visible above zero, not hidden in the
    /// 40% baseline.
    pub fn perturb(&self, rng: &mut Rng, base: &Mapping) -> Mapping {
        if self.check != SpaceCheck::Constructive {
            // no propagation on this space: order swaps are all we have
            telemetry::record_perturbation_fallback();
        } else if rng.chance(0.6) {
            let d = *rng.choose(&DIMS);
            if let Some(splits) =
                self.propagator().resplit(&base.splits, d, |_, _, adm| *rng.choose(adm))
            {
                let m = Mapping {
                    splits,
                    order_local: base.order_local,
                    order_glb: base.order_glb,
                    order_dram: base.order_dram,
                };
                // valid-by-construction for a feasible base; the cheap
                // cross-check catches caller-contract violations
                if check_mapping(&self.layer, &self.hw, &self.resources, &m).is_ok() {
                    telemetry::record_perturbation();
                    return m;
                }
            }
            // degradation: the resplit was refused or failed its check
            telemetry::record_perturbation_fallback();
        } else {
            // the deliberate order-swap arm of the move mixture
            telemetry::record_perturbation();
        }
        let mut m = base.clone();
        let order = match rng.below(3) {
            0 => &mut m.order_local,
            1 => &mut m.order_glb,
            _ => &mut m.order_dram,
        };
        let i = rng.below(6);
        let j = rng.below(6);
        order.swap(i, j);
        m
    }

    /// Deterministic nearest-feasible projection: re-run the propagation
    /// pass in canonical dimension order, picking from each admissible set
    /// the factor closest (in log space) to the target's factor at that
    /// level; loop orders carry over (sanitized to permutations). The output
    /// is feasible whenever the space is [`SpaceCheck::Constructive`] —
    /// this is how round-BO snaps relax-and-round points onto the feasible
    /// set instead of recording penalty observations.
    pub fn project(&self, target: &Mapping) -> Option<Mapping> {
        if self.check != SpaceCheck::Constructive {
            telemetry::record_projection_failure();
            return None;
        }
        let splits = self.propagator().construct(&[DIMS; 4], |d, slot, adm| {
            let s = target.split(d);
            let want = match slot {
                Slot::Local => s.local,
                Slot::SpatialX => s.spatial_x,
                Slot::SpatialY => s.spatial_y,
                Slot::Glb => s.glb,
            };
            nearest_in_log(adm, want)
        });
        let Some(splits) = splits else {
            telemetry::record_projection_failure();
            return None;
        };
        let keep = |o: &[Dim; 6]| if is_permutation(o) { *o } else { DIMS };
        telemetry::record_projection();
        Some(Mapping {
            splits,
            order_local: keep(&target.order_local),
            order_glb: keep(&target.order_glb),
            order_dram: keep(&target.order_dram),
        })
    }

    /// Number of constructive decisions a sample makes (for space sizing /
    /// diagnostics): dims x unpinned levels.
    pub fn decision_count(&self) -> usize {
        let pinned = self.lattices.iter().filter(|l| l.pinned_local.is_some()).count();
        DIMS.len() * SLOTS.len() - pinned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::validity::check_mapping;
    use crate::workloads::eyeriss::{eyeriss_hw, eyeriss_resources};
    use crate::workloads::specs::layer_by_name;

    fn sampler(layer: &str) -> FeasibleSampler {
        FeasibleSampler::new(
            layer_by_name(layer).unwrap(),
            eyeriss_hw(168),
            eyeriss_resources(168),
        )
    }

    #[test]
    fn samples_are_valid_and_diverse() {
        let fs = sampler("ResNet-K2");
        assert_eq!(fs.check(), SpaceCheck::Constructive);
        let mut rng = Rng::seed_from_u64(1);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..200 {
            let m = fs.sample(&mut rng).expect("constructive space");
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &m), Ok(()));
            distinct.insert(m);
        }
        assert!(distinct.len() > 150, "only {} distinct mappings", distinct.len());
    }

    #[test]
    fn perturb_stays_feasible_and_moves() {
        let fs = sampler("DQN-K2");
        let mut rng = Rng::seed_from_u64(2);
        let base = fs.sample(&mut rng).unwrap();
        let mut moved = 0;
        for _ in 0..200 {
            let p = fs.perturb(&mut rng, &base);
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &p), Ok(()));
            if p != base {
                moved += 1;
            }
        }
        assert!(moved > 100, "perturb moved only {moved}/200 times");
    }

    #[test]
    fn projection_is_deterministic_and_feasible() {
        let fs = sampler("DQN-K1");
        let mut rng = Rng::seed_from_u64(3);
        // a raw (usually invalid) draw from the unpropagated parameterization
        let sp = crate::space::sw_space::SwSpace::new(
            fs.layer.clone(),
            fs.hw.clone(),
            fs.resources.clone(),
        );
        for _ in 0..50 {
            let raw = sp.sample_raw(&mut rng);
            let a = fs.project(&raw).expect("constructive space");
            let b = fs.project(&raw).expect("constructive space");
            assert_eq!(a, b, "projection must be deterministic");
            assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &a), Ok(()));
            // loop orders carry over untouched
            assert_eq!(a.order_glb, raw.order_glb);
        }
    }

    #[test]
    fn projection_fixes_a_feasible_point_almost_in_place() {
        let fs = sampler("DQN-K2");
        let mut rng = Rng::seed_from_u64(4);
        let m = fs.sample(&mut rng).unwrap();
        let p = fs.project(&m).unwrap();
        assert_eq!(check_mapping(&fs.layer, &fs.hw, &fs.resources, &p), Ok(()));
        // the projection of an already-feasible mapping keeps its orders and
        // stays feasible; the splits may differ only through the witness's
        // conservative visit order, so at minimum the pinned axes agree
        assert_eq!(p.split(Dim::R).local, m.split(Dim::R).local);
        assert_eq!(p.order_dram, m.order_dram);
    }

    #[test]
    fn empty_space_is_detected_not_sampled() {
        // Shrink the weight buffer below the pinned 8x8 DQN-K1 filter tile.
        let mut hw = eyeriss_hw(168);
        hw.df_filter_w = crate::model::arch::DataflowOpt::FullAtPe;
        hw.lb_weights = 4;
        let fs = FeasibleSampler::new(
            layer_by_name("DQN-K1").unwrap(),
            hw,
            eyeriss_resources(168),
        );
        assert_eq!(fs.check(), SpaceCheck::ProvablyEmpty);
        let mut rng = Rng::seed_from_u64(5);
        assert!(fs.sample(&mut rng).is_none());
        assert!(fs.project(&Mapping::trivial(&fs.layer)).is_none());
    }

    #[test]
    fn decision_count_reflects_pinning() {
        let fs = sampler("DQN-K2");
        // 6 dims x 4 slots minus the two dataflow-pinned local decisions
        assert_eq!(fs.decision_count(), 22);
    }
}
