//! Software mapping space (S1-S9, paper Fig. 8) for a fixed (hardware,
//! layer) pair. All constraints are known here (Fig. 9). Since the
//! feasibility engine landed, valid candidates are generated *by
//! construction* through the constraint-propagation pass of
//! [`crate::space::feasible`] (one draw per valid mapping); the paper's
//! rejection sampling — uniform raw draws over the parameterization, ~22K
//! per 150 feasible points (~0.7% feasibility) — survives as
//! [`SwSpace::sample_valid_rejection`], used only as a cross-checked
//! fallback for the rare GLB-tight spaces where construction cannot start,
//! and as the baseline the `feasible_sampling` bench measures against.

use crate::model::arch::{DataflowOpt, HwConfig, Resources};
use crate::model::mapping::{Mapping, Split};
use crate::model::validity::check_mapping;
use crate::model::workload::{Dim, Layer, DIMS};
use crate::obs::span::{span, Phase};
use crate::space::factors::FactorSplitter;
use crate::space::feasible::{telemetry as feastel, FeasibleSampler};
use crate::util::rng::Rng;

/// Rejection-probe cap for GLB-tight spaces that carry a feasibility
/// witness: the exact certificate already proves the space non-empty, so
/// the rejection fallback is a bounded diversity probe (not a search) and
/// repeated `sample_valid` calls can never re-burn a caller's full
/// `max_draws` budget on a space that is resolved.
pub const WITNESS_PROBE_DRAWS: u64 = 2_048;

/// The mapping space for one layer on one hardware configuration.
#[derive(Clone, Debug)]
pub struct SwSpace {
    pub layer: Layer,
    pub hw: HwConfig,
    pub resources: Resources,
    /// Per-dimension prime multisets (hot-path: no re-factorization per
    /// draw); for dataflow-pinned dims this splits `size/pinned_local`.
    splitters: [FactorSplitter; 6],
    /// The constraint-propagating feasible-by-construction generator.
    feasible: FeasibleSampler,
}

impl SwSpace {
    pub fn new(layer: Layer, hw: HwConfig, resources: Resources) -> Self {
        let splitters = std::array::from_fn(|i| {
            let d = DIMS[i];
            let n = layer.size(d);
            let pinned = hw.dataflow_for(d).map(|opt| match opt {
                crate::model::arch::DataflowOpt::FullAtPe => layer.size(d),
                crate::model::arch::DataflowOpt::Streamed => 1,
            });
            FactorSplitter::new(n / pinned.unwrap_or(1))
        });
        let feasible = FeasibleSampler::new(layer.clone(), hw.clone(), resources.clone());
        SwSpace { layer, hw, resources, splitters, feasible }
    }

    /// The feasibility engine of this space.
    pub fn feasible(&self) -> &FeasibleSampler {
        &self.feasible
    }

    /// Uniform draw over the raw parameterization (may be invalid).
    /// Dataflow-pinned axes (H11/H12) have their local factor fixed by the
    /// hardware, exactly as the paper's Fig. 8 footnote excludes dims "that
    /// are in the hardware dataflow" from free blocking.
    pub fn sample_raw(&self, rng: &mut Rng) -> Mapping {
        let mut splits = [Split::unit(); 6];
        for d in DIMS {
            let splitter = &self.splitters[d.index()];
            let s = if let Some(loc) = self.pinned_local(d) {
                // local factor fixed; split the rest across 4 levels
                let mut v = [1u64; 4];
                splitter.split_into(rng, &mut v);
                Split { dram: v[0], glb: v[1], spatial_x: v[2], spatial_y: v[3], local: loc }
            } else {
                let mut v = [1u64; 5];
                splitter.split_into(rng, &mut v);
                Split { dram: v[0], glb: v[1], spatial_x: v[2], spatial_y: v[3], local: v[4] }
            };
            splits[d.index()] = s;
        }
        let mut order_local = DIMS;
        let mut order_glb = DIMS;
        let mut order_dram = DIMS;
        rng.shuffle(&mut order_local);
        rng.shuffle(&mut order_glb);
        rng.shuffle(&mut order_dram);
        Mapping { splits, order_local, order_glb, order_dram }
    }

    /// The local blocking factor forced by the hardware dataflow, if any.
    pub fn pinned_local(&self, d: Dim) -> Option<u64> {
        self.hw.dataflow_for(d).map(|opt| match opt {
            DataflowOpt::FullAtPe => self.layer.size(d),
            DataflowOpt::Streamed => 1,
        })
    }

    pub fn is_valid(&self, m: &Mapping) -> bool {
        check_mapping(&self.layer, &self.hw, &self.resources, m).is_ok()
    }

    /// One valid mapping and the raw draws it cost. Constructive first: the
    /// feasibility engine emits a valid-by-construction mapping in a single
    /// draw whenever the propagation pass can start. A space whose
    /// emptiness is *certified* — the pinned minimal tile overflows a local
    /// buffer, or a GLB-tight space whose exhaustive spatial witness search
    /// proved no mapping exists — returns `None` without burning a single
    /// raw draw; that is how the software optimizer detects the hardware's
    /// unknown-constraint violation ("valid mappings cannot be sampled",
    /// paper §4.2). The remaining case (GLB-tight with a known witness)
    /// runs the cross-checked rejection fallback — bounded at
    /// [`WITNESS_PROBE_DRAWS`], since the space is already resolved exactly
    /// and rejection only adds sample diversity, the caller's full budget
    /// must not be re-burned on every call — and on exhaustion degrades to
    /// the witness itself rather than mis-reporting a provably non-empty
    /// space as unsampleable. Exhaustion never panics.
    pub fn sample_valid(&self, rng: &mut Rng, max_draws: u64) -> Option<(Mapping, u64)> {
        let _span = span(Phase::Sample);
        if let Some(m) = self.feasible.sample(rng) {
            debug_assert!(self.is_valid(&m), "constructed mapping failed the validator");
            return Some((m, 1));
        }
        if self.feasible.certified_empty() {
            feastel::record_infeasible_space();
            return None;
        }
        // only a GLB-tight space with a known witness reaches this point
        let budget = max_draws.min(WITNESS_PROBE_DRAWS);
        match self.sample_valid_rejection(rng, budget) {
            Some((m, draws)) => {
                feastel::record_fallback_sample(draws);
                Some((m, draws))
            }
            None => {
                feastel::record_fallback_exhausted(budget);
                if let Some(w) = self.feasible.glb_witness() {
                    debug_assert!(self.is_valid(&w), "GLB-tight witness failed the validator");
                    // served from the cached witness, not constructed and
                    // not found by rejection: visible in telemetry as
                    // fallback draws without a fallback sample
                    return Some((w, budget));
                }
                feastel::record_infeasible_space();
                None
            }
        }
    }

    /// The pre-engine path: rejection-sample one valid mapping, returning
    /// the raw draw count, or `None` after `max_draws`. Kept as the
    /// feasibility engine's cross-checked fallback and as the baseline the
    /// `feasible_sampling` bench compares against.
    pub fn sample_valid_rejection(
        &self,
        rng: &mut Rng,
        max_draws: u64,
    ) -> Option<(Mapping, u64)> {
        for draws in 1..=max_draws {
            let m = self.sample_raw(rng);
            if self.is_valid(&m) {
                return Some((m, draws));
            }
        }
        None
    }

    /// Nearest-feasible projection of an arbitrary (typically rounded and
    /// invalid) mapping onto this space; `None` when the space admits no
    /// construction. Deterministic — see [`FeasibleSampler::project`].
    pub fn project_feasible(&self, target: &Mapping) -> Option<Mapping> {
        let m = self.feasible.project(target)?;
        debug_assert!(self.is_valid(&m), "projected mapping failed the validator");
        Some(m)
    }

    /// Feasibility-preserving local move (see [`FeasibleSampler::perturb`]):
    /// the perturbed mapping of a valid base is valid by construction and
    /// cross-checked against the validator before it is returned; a failed
    /// cross-check degrades to an always-safe loop-order swap.
    pub fn perturb_feasible(&self, rng: &mut Rng, base: &Mapping) -> Mapping {
        self.feasible.perturb(rng, base)
    }

    /// [`Self::perturb_feasible`] plus an exact [`MappingDelta`] describing
    /// the move, so perturbation-shaped searchers can route the candidate
    /// through [`crate::model::DeltaEvaluator`] without re-diffing. Draws the
    /// same RNG stream as `perturb_feasible`.
    pub fn perturb_feasible_described(
        &self,
        rng: &mut Rng,
        base: &Mapping,
    ) -> (Mapping, crate::model::MappingDelta) {
        self.feasible.perturb_described(rng, base)
    }

    /// Local move for simulated-annealing searchers: re-split one dimension
    /// or swap two loops in one order.
    pub fn perturb(&self, rng: &mut Rng, base: &Mapping) -> Mapping {
        let mut m = base.clone();
        if rng.chance(0.6) {
            let d = *rng.choose(&DIMS);
            let splitter = &self.splitters[d.index()];
            let s = if let Some(loc) = self.pinned_local(d) {
                let mut v = [1u64; 4];
                splitter.split_into(rng, &mut v);
                Split { dram: v[0], glb: v[1], spatial_x: v[2], spatial_y: v[3], local: loc }
            } else {
                let mut v = [1u64; 5];
                splitter.split_into(rng, &mut v);
                Split { dram: v[0], glb: v[1], spatial_x: v[2], spatial_y: v[3], local: v[4] }
            };
            m.splits[d.index()] = s;
        } else {
            let which = rng.below(3);
            let order = match which {
                0 => &mut m.order_local,
                1 => &mut m.order_glb,
                _ => &mut m.order_dram,
            };
            let i = rng.below(6);
            let j = rng.below(6);
            order.swap(i, j);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::eyeriss::{eyeriss_hw, eyeriss_resources};
    use crate::workloads::specs::layer_by_name;

    fn space(layer: &str) -> SwSpace {
        SwSpace::new(
            layer_by_name(layer).unwrap(),
            eyeriss_hw(168),
            eyeriss_resources(168),
        )
    }

    #[test]
    fn raw_samples_respect_factor_products_and_pinning() {
        let sp = space("DQN-K2");
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..200 {
            let m = sp.sample_raw(&mut rng);
            for d in DIMS {
                assert_eq!(m.split(d).product(), sp.layer.size(d));
            }
            // Eyeriss: R FullAtPe, S Streamed
            assert_eq!(m.split(Dim::R).local, sp.layer.r);
            assert_eq!(m.split(Dim::S).local, 1);
        }
    }

    #[test]
    fn valid_samples_exist_for_all_paper_layers() {
        for name in [
            "ResNet-K1", "ResNet-K2", "ResNet-K3", "ResNet-K4", "DQN-K1", "DQN-K2", "MLP-K1",
            "MLP-K2",
        ] {
            let sp = space(name);
            let mut rng = Rng::seed_from_u64(42);
            let got = sp.sample_valid(&mut rng, 2_000_000);
            let (m, draws) = got.expect("no valid mapping sampled");
            // all paper layers are constructive: one draw per valid mapping
            assert_eq!(draws, 1, "{name} fell back to rejection sampling");
            assert!(sp.is_valid(&m), "{name} produced an invalid construction");
        }
    }

    #[test]
    fn rejection_fallback_still_samples_the_same_spaces() {
        let sp = space("DQN-K2");
        let mut rng = Rng::seed_from_u64(42);
        let (m, draws) = sp.sample_valid_rejection(&mut rng, 2_000_000).unwrap();
        assert!(sp.is_valid(&m));
        assert!(draws >= 1);
    }

    #[test]
    fn perturb_feasible_preserves_validity() {
        let sp = space("DQN-K1");
        let mut rng = Rng::seed_from_u64(6);
        let (mut cur, _) = sp.sample_valid(&mut rng, 1_000_000).unwrap();
        for _ in 0..200 {
            cur = sp.perturb_feasible(&mut rng, &cur);
            assert!(sp.is_valid(&cur), "perturb_feasible left the feasible set");
        }
    }

    #[test]
    fn projection_repairs_invalid_raw_draws() {
        let sp = space("ResNet-K2");
        let mut rng = Rng::seed_from_u64(8);
        let mut repaired = 0;
        for _ in 0..50 {
            let raw = sp.sample_raw(&mut rng);
            if sp.is_valid(&raw) {
                continue;
            }
            let p = sp.project_feasible(&raw).expect("constructive space");
            assert!(sp.is_valid(&p));
            repaired += 1;
        }
        assert!(repaired > 10, "raw draws should mostly be invalid (got {repaired})");
    }

    #[test]
    fn feasibility_ratio_matches_paper_regime() {
        // The paper reports ~150 feasible in ~22K draws (~0.7%). Check we
        // are within an order of magnitude on a representative layer.
        let sp = space("ResNet-K2");
        let mut rng = Rng::seed_from_u64(7);
        let total = 30_000;
        let valid = (0..total).filter(|_| sp.is_valid(&sp.sample_raw(&mut rng))).count();
        let ratio = valid as f64 / total as f64;
        assert!(
            ratio > 0.0001 && ratio < 0.25,
            "feasibility ratio {ratio} outside the constrained regime"
        );
    }

    #[test]
    fn perturb_preserves_factor_products() {
        let sp = space("DQN-K1");
        let mut rng = Rng::seed_from_u64(3);
        let (base, _) = sp.sample_valid(&mut rng, 1_000_000).unwrap();
        for _ in 0..100 {
            let p = sp.perturb(&mut rng, &base);
            for d in DIMS {
                assert_eq!(p.split(d).product(), sp.layer.size(d));
            }
        }
    }

    #[test]
    fn certified_empty_tight_space_skips_the_rejection_budget() {
        // the shared hand-computed GLB-tight fixture (see
        // `space::feasible::fixtures`): capacity 11 admits nothing,
        // capacity 12 admits only sx[P]=2
        let tight = |glb_entries: u64| {
            let (layer, hw, res) =
                crate::space::feasible::fixtures::tight_fixture(glb_entries);
            SwSpace::new(layer, hw, res)
        };
        // proven empty: None, instantly — the exact certificate replaces
        // the old rejection-budget burn
        let sp = tight(11);
        let mut rng = Rng::seed_from_u64(1);
        assert!(sp.feasible().certified_empty());
        assert!(sp.sample_valid(&mut rng, 1_000_000).is_none());
        // tight but feasible: rejection may serve it, and with a zero draw
        // budget the witness itself is the degradation path
        let sp = tight(12);
        let mut rng = Rng::seed_from_u64(2);
        assert!(!sp.feasible().certified_empty());
        let (m, draws) = sp.sample_valid(&mut rng, 0).expect("witness must back the space");
        assert_eq!(draws, 0, "the witness is free");
        assert!(sp.is_valid(&m));
        assert_eq!(m.split(Dim::P).spatial_x, 2, "only the spread-P witness fits");
    }

    #[test]
    fn transformer_layers_sample_on_256_pe_budget() {
        let sp = SwSpace::new(
            layer_by_name("Transformer-K1").unwrap(),
            eyeriss_hw(256),
            eyeriss_resources(256),
        );
        let mut rng = Rng::seed_from_u64(5);
        assert!(sp.sample_valid(&mut rng, 2_000_000).is_some());
    }
}
