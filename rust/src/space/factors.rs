//! Integer factorization utilities for the semi-discrete design space: the
//! valid values of most parameters are *divisors* of a workload dimension or
//! of a hardware resource count (paper Figs. 6 and 8), and blocking factors
//! must multiply out exactly, so sampling happens in factorization space.

use crate::util::rng::Rng;

/// All divisors of n, ascending. n >= 1.
pub fn divisors(n: u64) -> Vec<u64> {
    assert!(n >= 1);
    let mut small = Vec::new();
    let mut big = Vec::new();
    let mut f = 1;
    while f * f <= n {
        if n % f == 0 {
            small.push(f);
            if f != n / f {
                big.push(n / f);
            }
        }
        f += 1;
    }
    big.reverse();
    small.extend(big);
    small
}

/// Prime factorization as (prime, exponent) pairs, ascending primes.
pub fn prime_factorization(mut n: u64) -> Vec<(u64, u32)> {
    assert!(n >= 1);
    let mut out = Vec::new();
    let mut p = 2;
    while p * p <= n {
        if n % p == 0 {
            let mut e = 0;
            while n % p == 0 {
                n /= p;
                e += 1;
            }
            out.push((p, e));
        }
        p += 1;
    }
    if n > 1 {
        out.push((n, 1));
    }
    out
}

/// Uniformly sample an ordered split of `n` into `k` factors whose product is
/// exactly `n`, by distributing each prime's exponent multinomially across
/// the k slots. Every valid split has non-zero probability.
pub fn random_factor_split(rng: &mut Rng, n: u64, k: usize) -> Vec<u64> {
    FactorSplitter::new(n).split(rng, k)
}

/// Precomputed prime multiset of a fixed n, for the rejection-sampling hot
/// path (the samplers draw tens of thousands of splits of the *same* layer
/// dimensions; re-factorizing per draw dominated the §Perf baseline profile).
#[derive(Clone, Debug)]
pub struct FactorSplitter {
    n: u64,
    /// primes with multiplicity, e.g. 12 -> [2, 2, 3]
    primes: Vec<u64>,
}

impl FactorSplitter {
    pub fn new(n: u64) -> Self {
        let primes = prime_factorization(n)
            .into_iter()
            .flat_map(|(p, e)| std::iter::repeat(p).take(e as usize))
            .collect();
        FactorSplitter { n, primes }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Draw an ordered k-way split with product exactly n.
    pub fn split(&self, rng: &mut Rng, k: usize) -> Vec<u64> {
        assert!(k >= 1);
        let mut slots = vec![1u64; k];
        self.split_into(rng, &mut slots);
        slots
    }

    /// Allocation-free variant: fill `slots` (len >= 1) in place.
    #[inline]
    pub fn split_into(&self, rng: &mut Rng, slots: &mut [u64]) {
        slots.fill(1);
        let k = slots.len();
        for &p in &self.primes {
            slots[rng.below(k)] *= p;
        }
        debug_assert_eq!(slots.iter().product::<u64>(), self.n);
    }
}

/// Number of ordered k-factor splits of n (for sanity checks / space sizing):
/// prod over primes of C(e + k - 1, k - 1).
pub fn count_factor_splits(n: u64, k: usize) -> u128 {
    let mut total: u128 = 1;
    for (_, e) in prime_factorization(n) {
        total *= binomial(e as u128 + k as u128 - 1, k as u128 - 1);
    }
    total
}

fn binomial(n: u128, k: u128) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) / (i + 1);
    }
    acc
}

/// Pairs (a, b) with a*b = n (ordered). The valid values of H1/H2 ("factors
/// of #PEs" with H1*H2 = #PEs).
pub fn factor_pairs(n: u64) -> Vec<(u64, u64)> {
    divisors(n).into_iter().map(|a| (a, n / a)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn divisors_known() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(divisors(168).len(), 16);
    }

    #[test]
    fn prime_factorization_known() {
        assert_eq!(prime_factorization(1), vec![]);
        assert_eq!(prime_factorization(12), vec![(2, 2), (3, 1)]);
        assert_eq!(prime_factorization(97), vec![(97, 1)]);
        assert_eq!(prime_factorization(168), vec![(2, 3), (3, 1), (7, 1)]);
    }

    #[test]
    fn random_split_products_always_exact() {
        let mut rng = Rng::seed_from_u64(1);
        for n in [1u64, 7, 12, 56, 168, 512, 224] {
            for k in 1..=5 {
                let s = random_factor_split(&mut rng, n, k);
                assert_eq!(s.len(), k);
                assert_eq!(s.iter().product::<u64>(), n, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn random_split_covers_space() {
        // 12 into 2 slots: 6 ordered splits; all should appear.
        let mut rng = Rng::seed_from_u64(2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            let s = random_factor_split(&mut rng, 12, 2);
            seen.insert((s[0], s[1]));
        }
        assert_eq!(seen.len() as u128, count_factor_splits(12, 2));
    }

    #[test]
    fn count_splits_known() {
        // 12 = 2^2*3: C(3,1)*C(2,1) = 6 ordered pairs
        assert_eq!(count_factor_splits(12, 2), 6);
        assert_eq!(count_factor_splits(1, 4), 1);
        // 8 = 2^3 into 3 slots: C(5,2) = 10
        assert_eq!(count_factor_splits(8, 3), 10);
    }

    #[test]
    fn factor_pairs_multiply_out() {
        for (a, b) in factor_pairs(168) {
            assert_eq!(a * b, 168);
        }
        assert_eq!(factor_pairs(168).len(), 16);
    }
}
