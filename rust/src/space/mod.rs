//! Design-space parameterization: factorization utilities, the
//! constraint-propagating feasibility engine (see `README.md` in this
//! directory), the cross-space pruner certifying hardware points against a
//! target layer set, the hardware (H1-H12) and software (S1-S9) samplers,
//! and the Fig. 13 feature transforms feeding the BO surrogates.

pub mod factors;
pub mod feasible;
pub mod features;
pub mod hw_space;
pub mod prune;
pub mod sw_space;

pub use feasible::{FactorRange, FeasibleSampler, Slot, SpaceCheck, SLOTS};
pub use features::{hw_features, sw_features, FEATURE_DIM};
pub use hw_space::HwSpace;
pub use prune::{HwCertificate, PrunedHwSpace};
pub use sw_space::SwSpace;
