//! Cross-space pruning: certify the hardware design space against a target
//! layer set *before* any simulator evaluation.
//!
//! The joint hw/sw space is profitable exactly where the two sub-spaces
//! interact (CODEBench, Tuli et al. 2022; the semi-decoupled search of Lu
//! et al. 2022 — both named in ROADMAP's feasibility-engine entry): a
//! hardware configuration whose *mapping space* is empty for some layer of
//! the target network can never win, yet the plain hardware search only
//! discovers that by paying a full inner software search for the config.
//! [`PrunedHwSpace`] closes the gap by reusing the PR-4 constraint
//! propagation: for a candidate [`HwConfig`] it computes, per target layer,
//! the feasibility certificate of the (layer, hardware) mapping space —
//! [`SpaceCheck::Constructive`] / [`SpaceCheck::ProvablyEmpty`] /
//! [`SpaceCheck::GlbTight`] — from the divisor lattices and the capacity
//! arithmetic alone, **without sampling a single mapping**.
//!
//! The certificates are exact (property-tested in
//! `rust/tests/prune_soundness.rs`):
//!
//! * `ProvablyEmpty` is a proof — rejection sampling can never find a
//!   mapping there, at any budget (footprints are monotone in the pinned
//!   minimal tile);
//! * `Constructive` is a witness — one constructive draw always succeeds;
//! * `GlbTight` is resolved *exactly* by the exhaustive spatial witness
//!   search (`FeasibleSampler::certified_empty`): either a feasibility
//!   witness exists, or emptiness is proven — so tight spaces are pruned
//!   precisely when no mapping exists, never on a guess.
//!
//! [`PrunedHwSpace::sample_valid`] therefore rejects hardware points whose
//! mapping space is provably empty for any target layer before they ever
//! reach the simulator (telemetry: `prune_certificates` /
//! `prune_rejections` through [`telemetry`] into `coordinator::metrics`),
//! and [`PrunedHwSpace::admissible_ranges`] reports the per-dimension
//! lattice-admissible factor ranges a configuration leaves the software
//! search — the same ranges round-BO's lattice box is derived from.
//!
//! Certificates are **pure functions** of (layer, hardware point, resource
//! budget), so they are memoized: every `PrunedHwSpace` is backed by a
//! [`CertificateStore`] — private by default, or shared across spaces (and
//! across concurrent jobs, via `runtime::jobs::JobScheduler`) through
//! [`PrunedHwSpace::with_store`]. Store traffic is counted as
//! `prune_cert_hits` / `prune_cert_misses` in the feasibility telemetry.

use std::collections::{BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

use crate::model::arch::{DataflowOpt, HwConfig, Resources};
use crate::model::workload::Layer;
use crate::obs::span::{span, Phase};
use crate::space::feasible::{telemetry, FactorRange, FeasibleSampler, SpaceCheck};
use crate::space::hw_space::HwSpace;
use crate::util::rng::Rng;
use crate::util::sync::lock_unpoisoned;

/// How many provably-empty candidates [`PrunedHwSpace::sample_valid`]
/// discards before giving up and handing back an uncertified draw (the
/// inner software search then reports the unknown-constraint violation,
/// exactly as it would have pre-pruning — liveness is never traded for the
/// optimization).
const MAX_PRUNE_REJECTS: u32 = 256;

/// Per-layer feasibility certificates of one hardware configuration
/// against a target layer set, in layer order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HwCertificate {
    /// Propagation start check per layer.
    pub per_layer: Vec<SpaceCheck>,
    /// Exact per-layer emptiness: `true` for a pinned-overflow proof *and*
    /// for a GLB-tight space whose exhaustive spatial witness search proved
    /// no mapping exists.
    pub empty: Vec<bool>,
}

impl HwCertificate {
    /// No target layer's mapping space is provably empty: the configuration
    /// may reach the simulator. (GLB-tight layers pass exactly when a
    /// feasibility witness exists.)
    pub fn admits_all(&self) -> bool {
        !self.empty.iter().any(|&e| e)
    }

    /// Every target layer's space is constructive: the inner search is
    /// guaranteed one-draw candidate generation on all of them.
    pub fn constructive_for_all(&self) -> bool {
        self.per_layer.iter().all(|c| *c == SpaceCheck::Constructive)
    }

    /// Number of target layers whose mapping space is provably empty.
    pub fn empty_layers(&self) -> usize {
        self.empty.iter().filter(|&&e| e).count()
    }
}

/// One memoized per-layer certificate: the propagation start check plus the
/// exact emptiness resolution. A pure function of its [`CertKey`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerCertificate {
    /// Propagation start check (`FeasibleSampler::check`).
    pub check: SpaceCheck,
    /// Exact emptiness (`FeasibleSampler::certified_empty`), including the
    /// GLB-tight witness-search resolution.
    pub empty: bool,
}

/// Injective memo key for one certificate. Certificates depend on the layer
/// shape, the hardware point, and the resource budget — nothing else — so
/// the key captures all three exactly (the f64 bandwidth fields keyed by
/// their IEEE bit patterns; no lossy hashing, `HashMap` resolves bucket
/// collisions through full key equality).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct CertKey {
    layer: Layer,
    hw: HwConfig,
    num_pes: u64,
    local_buffer_entries: u64,
    global_buffer_entries: u64,
    dram_bw_bits: u64,
    gb_bw_bits: u64,
}

impl CertKey {
    fn new(layer: &Layer, hw: &HwConfig, res: &Resources) -> Self {
        CertKey {
            layer: layer.clone(),
            hw: hw.clone(),
            num_pes: res.num_pes,
            local_buffer_entries: res.local_buffer_entries,
            global_buffer_entries: res.global_buffer_entries,
            dram_bw_bits: res.dram_words_per_cycle.to_bits(),
            gb_bw_bits: res.gb_words_per_cycle_per_instance.to_bits(),
        }
    }
}

/// Cross-run memo of per-(layer, hardware, resources) certificates.
/// Certificates are pure, so entries computed by one run (or one concurrent
/// job) are valid for every other — the scheduler shares a single store
/// across all jobs it multiplexes. Lookups are counted as
/// `prune_cert_hits` / `prune_cert_misses` in the feasibility telemetry.
#[derive(Debug, Default)]
pub struct CertificateStore {
    map: Mutex<HashMap<CertKey, LayerCertificate>>,
}

impl CertificateStore {
    pub fn new() -> Self {
        CertificateStore::default()
    }

    /// Number of distinct certificates currently memoized.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up the certificate for `key`, or compute and share it. The
    /// compute runs *outside* the lock: two threads missing on the same key
    /// may both compute (the results are identical — certificates are
    /// pure), but a slow witness search never blocks other lookups.
    fn lookup_or(
        &self,
        key: CertKey,
        compute: impl FnOnce() -> LayerCertificate,
    ) -> LayerCertificate {
        if let Some(cert) = lock_unpoisoned(&self.map).get(&key) {
            telemetry::record_cert_hit();
            return *cert;
        }
        telemetry::record_cert_miss();
        let cert = compute();
        lock_unpoisoned(&self.map).insert(key, cert);
        cert
    }
}

/// Quantized lattice cell of one hardware configuration: the coordinates
/// along which per-layer optimal mappings actually move. Configurations
/// sharing a cell have the same PE mesh, dataflow pair, and (bucketed)
/// local-buffer partition; GLB bank geometry is deliberately excluded — it
/// shifts EDP but barely moves the *mapping* optimum, and keying on it
/// would fragment the table. Built by [`PrunedHwSpace::cell_key`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct HwCellKey {
    pub pe_mesh_x: u64,
    pub pe_mesh_y: u64,
    pub df_filter_w: DataflowOpt,
    pub df_filter_h: DataflowOpt,
    /// `lb_inputs` quantized into `lb_buckets` slices of the spad budget.
    pub lb_inputs_bucket: u64,
    pub lb_weights_bucket: u64,
    pub lb_outputs_bucket: u64,
}

/// One enumerated cell of the certified-nonempty hardware lattice region:
/// the cell key, a certified representative configuration, and the
/// per-dimension admissible factor ranges that representative leaves the
/// software search. Produced by
/// [`PrunedHwSpace::enumerate_certified_cells`].
#[derive(Clone, Debug)]
pub struct CertifiedCell {
    pub key: HwCellKey,
    pub representative: HwConfig,
    pub ranges: [crate::space::feasible::FactorRange; 6],
}

/// The hardware design space pruned against a target layer set. Construct
/// one per co-design run (the run state machine does) and share it with the
/// hardware search loops; an empty layer set
/// ([`PrunedHwSpace::unconstrained`]) degrades to the plain constructive
/// sampler for synthetic objectives.
#[derive(Clone, Debug)]
pub struct PrunedHwSpace {
    inner: HwSpace,
    layers: Vec<Layer>,
    certs: Arc<CertificateStore>,
}

impl PrunedHwSpace {
    pub fn new(resources: Resources, layers: Vec<Layer>) -> Self {
        PrunedHwSpace::with_store(resources, layers, Arc::new(CertificateStore::default()))
    }

    /// A pruned space backed by a shared certificate memo: spaces built for
    /// different runs (or concurrent jobs) over the same layers and budget
    /// reuse each other's certificates instead of re-running the witness
    /// searches.
    pub fn with_store(
        resources: Resources,
        layers: Vec<Layer>,
        certs: Arc<CertificateStore>,
    ) -> Self {
        PrunedHwSpace { inner: HwSpace::new(resources), layers, certs }
    }

    /// The certificate memo backing this space.
    pub fn certificate_store(&self) -> &Arc<CertificateStore> {
        &self.certs
    }

    /// A pruned space with no target layers: every certificate passes
    /// trivially. Used by searches over synthetic objectives (tests,
    /// benches) where no workload exists to prune against.
    pub fn unconstrained(resources: Resources) -> Self {
        PrunedHwSpace::new(resources, Vec::new())
    }

    /// The underlying (unpruned) hardware space.
    pub fn space(&self) -> &HwSpace {
        &self.inner
    }

    pub fn resources(&self) -> &Resources {
        &self.inner.resources
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Per-layer feasibility certificates of `hw`, from the propagation
    /// start check and — on GLB-tight layers — the exhaustive spatial
    /// witness search (no mapping is ever *sampled*). Each layer's
    /// certificate is memoized in the backing [`CertificateStore`]; a cold
    /// lookup costs one divisor-lattice build and one capacity evaluation
    /// (tight layers add the mesh-bounded witness enumeration), a warm one
    /// costs a map probe.
    pub fn certify(&self, hw: &HwConfig) -> HwCertificate {
        let _span = span(Phase::Prune);
        telemetry::record_certificates(self.layers.len() as u64);
        let mut per_layer = Vec::with_capacity(self.layers.len());
        let mut empty = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let cert = self.layer_certificate(layer, hw);
            per_layer.push(cert.check);
            empty.push(cert.empty);
        }
        HwCertificate { per_layer, empty }
    }

    /// Short-circuiting admission test for the sampling hot path: stops at
    /// the first layer with a proven-empty mapping space (recording only
    /// the certificates it actually consulted).
    pub fn admits(&self, hw: &HwConfig) -> bool {
        for layer in &self.layers {
            telemetry::record_certificates(1);
            if self.layer_certificate(layer, hw).empty {
                return false;
            }
        }
        true
    }

    fn layer_certificate(&self, layer: &Layer, hw: &HwConfig) -> LayerCertificate {
        let key = CertKey::new(layer, hw, &self.inner.resources);
        self.certs.lookup_or(key, || {
            let fs = self.layer_sampler(layer, hw);
            LayerCertificate { check: fs.check(), empty: fs.certified_empty() }
        })
    }

    fn layer_sampler(&self, layer: &Layer, hw: &HwConfig) -> FeasibleSampler {
        FeasibleSampler::new(layer.clone(), hw.clone(), self.inner.resources.clone())
    }

    /// One hardware configuration that satisfies the known Fig. 7
    /// constraints by construction *and* whose mapping space is not provably
    /// empty for any target layer, plus the raw draws it cost (rejected
    /// candidates included — they cost one draw each but zero simulator
    /// evaluations, which is the point). After [`MAX_PRUNE_REJECTS`]
    /// consecutive empty certificates the next uncertified draw is returned
    /// so callers always make progress; the inner search then surfaces the
    /// unknown constraint as before.
    pub fn sample_valid(&self, rng: &mut Rng) -> (HwConfig, u64) {
        let _span = span(Phase::Prune);
        let mut draws = 0u64;
        for _ in 0..MAX_PRUNE_REJECTS {
            let (hw, d) = self.inner.sample_valid(rng);
            draws += d;
            if self.admits(&hw) {
                return (hw, draws);
            }
            telemetry::record_prune_rejection();
        }
        let (hw, d) = self.inner.sample_valid(rng);
        (hw, draws + d)
    }

    /// The quantized lattice cell of a hardware configuration: the PE mesh,
    /// the dataflow pair, and the local-buffer partition bucketed into
    /// `lb_buckets` slices of the budget. Configurations sharing a cell see
    /// near-identical mapping lattices (the mesh bounds spatial factors,
    /// the dataflow pins R/S, the partition caps local tiles), which is the
    /// granularity the semi-decoupled mapping tables key on — see
    /// `opt::semi_decoupled`.
    pub fn cell_key(&self, hw: &HwConfig, lb_buckets: u64) -> HwCellKey {
        let total = self.inner.resources.local_buffer_entries;
        let b = lb_buckets.max(1);
        let bucket = |words: u64| {
            if total == 0 {
                0
            } else {
                (words * b / total).min(b - 1)
            }
        };
        HwCellKey {
            pe_mesh_x: hw.pe_mesh_x,
            pe_mesh_y: hw.pe_mesh_y,
            df_filter_w: hw.df_filter_w,
            df_filter_h: hw.df_filter_h,
            lb_inputs_bucket: bucket(hw.lb_inputs),
            lb_weights_bucket: bucket(hw.lb_weights),
            lb_outputs_bucket: bucket(hw.lb_outputs),
        }
    }

    /// Enumerate the certified-nonempty region of the pruned hardware
    /// lattice as distinct [`HwCellKey`] cells, each carrying one
    /// certified representative configuration and its per-dimension
    /// admissible factor ranges. Discovery is constructive-draw-driven
    /// (`cell_draws` draws, first representative per cell wins, stops at
    /// `max_cells`), so the result is deterministic for a fixed seed; the
    /// certificates consulted are memoized in the backing
    /// [`CertificateStore`], so re-enumeration across runs is cheap.
    /// Candidates whose admissible ranges flag an unblockable dimension are
    /// skipped even when uncertified draws degrade past the prune budget —
    /// every returned representative admits all target layers.
    pub fn enumerate_certified_cells(
        &self,
        lb_buckets: u64,
        max_cells: usize,
        cell_draws: usize,
        rng: &mut Rng,
    ) -> Vec<CertifiedCell> {
        let _span = span(Phase::Prune);
        let mut seen: std::collections::HashSet<HwCellKey> = std::collections::HashSet::new();
        let mut out: Vec<CertifiedCell> = Vec::new();
        for _ in 0..cell_draws {
            if out.len() >= max_cells {
                break;
            }
            let (hw, _) = self.sample_valid(rng);
            let key = self.cell_key(&hw, lb_buckets);
            if seen.contains(&key) {
                continue;
            }
            // sample_valid degrades to an uncertified draw after its prune
            // budget: re-certify so provably-empty representatives never
            // enter a table
            if !self.admits(&hw) {
                continue;
            }
            let ranges = self.admissible_ranges(&hw);
            if ranges.iter().any(|r| r.count == 0) {
                continue;
            }
            seen.insert(key.clone());
            out.push(CertifiedCell { key, representative: hw, ranges });
        }
        out
    }

    /// Per loop dimension, the union over all target layers (and all four
    /// constructive slots) of the lattice-admissible blocking factors `hw`
    /// leaves the software search — the pruned space's per-dimension
    /// admissible report. `count` is the number of distinct admissible
    /// values in the union; a zero count marks a dimension some layer can
    /// not block at all (the provably-empty signature).
    pub fn admissible_ranges(&self, hw: &HwConfig) -> [FactorRange; 6] {
        let mut unions: [BTreeSet<u64>; 6] = std::array::from_fn(|_| BTreeSet::new());
        let mut emptied = [false; 6];
        for layer in &self.layers {
            let fs = self.layer_sampler(layer, hw);
            let sets = fs.lattice_sets();
            // slot-major: each entry holds the six per-dimension value sets
            // of one constructive slot
            for per_slot in &sets {
                for (i, vals) in per_slot.iter().enumerate() {
                    if vals.is_empty() {
                        emptied[i] = true;
                    }
                    unions[i].extend(vals.iter().copied());
                }
            }
        }
        std::array::from_fn(|i| {
            let set = &unions[i];
            match (set.first(), set.last()) {
                (Some(&min), Some(&max)) if !emptied[i] => {
                    FactorRange { min, max, count: set.len() }
                }
                (Some(&min), Some(&max)) => FactorRange { min, max, count: 0 },
                _ => FactorRange { min: 1, max: 1, count: 0 },
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch::DataflowOpt;
    use crate::model::workload::Dim;
    use crate::workloads::eyeriss::eyeriss_hw;
    use crate::workloads::specs::dqn;

    fn dqn_pruned() -> PrunedHwSpace {
        PrunedHwSpace::new(Resources::eyeriss_168(), dqn().layers)
    }

    /// A configuration whose pinned 8x8 DQN-K1 weight tile (64 words)
    /// overflows the weight sub-buffer: provably empty for DQN-K1.
    fn empty_for_dqn_k1() -> HwConfig {
        let mut hw = eyeriss_hw(168);
        hw.df_filter_w = DataflowOpt::FullAtPe;
        hw.df_filter_h = DataflowOpt::FullAtPe;
        hw.lb_weights = 32;
        hw.lb_inputs = 172;
        hw.lb_outputs = 16;
        hw
    }

    #[test]
    fn eyeriss_is_certified_constructive_for_dqn() {
        let pruned = dqn_pruned();
        let cert = pruned.certify(&eyeriss_hw(168));
        assert_eq!(cert.per_layer.len(), 2);
        assert!(cert.admits_all());
        assert!(cert.constructive_for_all());
        assert_eq!(cert.empty_layers(), 0);
        assert!(pruned.admits(&eyeriss_hw(168)));
    }

    #[test]
    fn pinned_overflow_is_certified_empty_and_rejected() {
        let pruned = dqn_pruned();
        let hw = empty_for_dqn_k1();
        assert_eq!(hw.check(pruned.resources()), Ok(()), "fixture must be Fig.7-valid");
        let cert = pruned.certify(&hw);
        assert_eq!(cert.per_layer[0], SpaceCheck::ProvablyEmpty, "DQN-K1 must be empty");
        assert!(!cert.admits_all());
        assert!(cert.empty_layers() >= 1);
        assert!(!pruned.admits(&hw));
    }

    #[test]
    fn unconstrained_space_admits_everything() {
        let pruned = PrunedHwSpace::unconstrained(Resources::eyeriss_168());
        let cert = pruned.certify(&empty_for_dqn_k1());
        assert!(cert.per_layer.is_empty());
        assert!(cert.admits_all());
        let mut rng = Rng::seed_from_u64(1);
        // degrades to the plain constructive sampler: one draw per config
        for _ in 0..50 {
            let (hw, draws) = pruned.sample_valid(&mut rng);
            assert_eq!(draws, 1);
            assert_eq!(hw.check(pruned.resources()), Ok(()));
        }
    }

    #[test]
    fn pruned_sampling_rejects_empty_configs_before_evaluation() {
        let pruned = dqn_pruned();
        let before = telemetry::snapshot();
        let mut rng = Rng::seed_from_u64(7);
        let mut total_draws = 0u64;
        for _ in 0..200 {
            let (hw, draws) = pruned.sample_valid(&mut rng);
            total_draws += draws;
            // every returned configuration is admissible...
            assert!(pruned.certify(&hw).admits_all());
            assert_eq!(hw.check(pruned.resources()), Ok(()));
        }
        // ...and the 8x8 DQN-K1 filters make double-FullAtPe small-buffer
        // draws common enough that the pruner must actually have fired
        let delta = telemetry::snapshot().since(&before);
        assert!(delta.prune_rejections >= 1, "no rejection in 200 samples: {delta:?}");
        assert!(total_draws > 200, "rejected draws must be accounted: {total_draws}");
        assert!(delta.prune_certificates >= 200, "certificates must be counted: {delta:?}");
    }

    #[test]
    fn glb_tight_layers_are_pruned_exactly() {
        // the shared hand-computed GLB-tight fixture (see
        // `space::feasible::fixtures`) as a one-layer target set: capacity
        // 12 keeps a witness, capacity 11 is proven empty — the pruner must
        // track that boundary exactly
        let fixture = crate::space::feasible::fixtures::tight_fixture;
        let (layer, hw, res) = fixture(12);
        let feasible = PrunedHwSpace::new(res, vec![layer]);
        let cert = feasible.certify(&hw);
        assert_eq!(cert.per_layer, vec![SpaceCheck::GlbTight]);
        assert!(cert.admits_all(), "tight-but-feasible must not be pruned");
        let (layer, hw, res) = fixture(11);
        let empty = PrunedHwSpace::new(res, vec![layer]);
        let cert = empty.certify(&hw);
        assert_eq!(cert.per_layer, vec![SpaceCheck::GlbTight]);
        assert!(!cert.admits_all(), "tight-and-proven-empty must be pruned");
        assert_eq!(cert.empty_layers(), 1);
    }

    #[test]
    fn certificates_are_memoized_across_spaces_sharing_a_store() {
        let store = Arc::new(CertificateStore::default());
        let a = PrunedHwSpace::with_store(
            Resources::eyeriss_168(),
            dqn().layers,
            Arc::clone(&store),
        );
        let hw = eyeriss_hw(168);
        assert!(store.is_empty());
        assert!(a.admits(&hw));
        assert_eq!(store.len(), 2, "one certificate per DQN layer");
        // a second space (another job) sharing the store serves the same
        // lookups from the memo
        let b = PrunedHwSpace::with_store(
            Resources::eyeriss_168(),
            dqn().layers,
            Arc::clone(&store),
        );
        let before = telemetry::snapshot();
        assert!(b.admits(&hw));
        let delta = telemetry::snapshot().since(&before);
        assert!(delta.cert_hits >= 2, "memoized lookups must be counted: {delta:?}");
        assert_eq!(store.len(), 2, "no recomputation, no new entries");
        // memoized admission equals a fresh computation
        let fresh = PrunedHwSpace::new(Resources::eyeriss_168(), dqn().layers);
        assert_eq!(b.admits(&hw), fresh.admits(&hw));
        assert_eq!(b.certify(&hw), fresh.certify(&hw));
    }

    #[test]
    fn memoized_certificates_preserve_empty_verdicts() {
        let store = Arc::new(CertificateStore::default());
        let pruned = PrunedHwSpace::with_store(
            Resources::eyeriss_168(),
            dqn().layers,
            Arc::clone(&store),
        );
        let hw = empty_for_dqn_k1();
        // first consult computes, second serves the memoized proof
        assert!(!pruned.admits(&hw));
        assert!(!pruned.admits(&hw));
        let cert = pruned.certify(&hw);
        assert_eq!(cert.per_layer[0], SpaceCheck::ProvablyEmpty);
        assert!(!cert.admits_all());
    }

    #[test]
    fn cell_enumeration_is_deterministic_deduped_and_certified() {
        let pruned = dqn_pruned();
        let mut r1 = Rng::seed_from_u64(11);
        let cells = pruned.enumerate_certified_cells(3, 12, 256, &mut r1);
        assert!(!cells.is_empty(), "DQN lattice must yield certified cells");
        assert!(cells.len() <= 12, "max_cells must cap enumeration");
        let mut keys = std::collections::HashSet::new();
        for c in &cells {
            assert!(keys.insert(c.key.clone()), "duplicate cell key {:?}", c.key);
            assert_eq!(c.key, pruned.cell_key(&c.representative, 3));
            assert!(pruned.certify(&c.representative).admits_all());
            assert_eq!(c.representative.check(pruned.resources()), Ok(()));
            assert!(c.ranges.iter().all(|r| r.count > 0), "{:?}", c.ranges);
        }
        // same seed -> same cells in the same order, representatives included
        let mut r2 = Rng::seed_from_u64(11);
        let again = pruned.enumerate_certified_cells(3, 12, 256, &mut r2);
        assert_eq!(again.len(), cells.len());
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.representative, b.representative);
        }
    }

    #[test]
    fn cell_key_buckets_partition_axes() {
        let pruned = dqn_pruned();
        let hw = eyeriss_hw(168);
        let key = pruned.cell_key(&hw, 3);
        assert_eq!((key.pe_mesh_x, key.pe_mesh_y), (14, 12));
        // 12/192/16 of 220 with 3 buckets: 12*3/220=0, 192*3/220=2, 16*3/220=0
        assert_eq!(key.lb_inputs_bucket, 0);
        assert_eq!(key.lb_weights_bucket, 2);
        assert_eq!(key.lb_outputs_bucket, 0);
        // bucket is clamped to lb_buckets-1 even at the full budget
        let mut big = hw.clone();
        big.lb_weights = 220;
        assert_eq!(pruned.cell_key(&big, 3).lb_weights_bucket, 2);
    }

    #[test]
    fn admissible_ranges_union_layers_and_flag_empty_dims() {
        let pruned = dqn_pruned();
        let ranges = pruned.admissible_ranges(&eyeriss_hw(168));
        // P spans both layers: DQN-K1 has P=20, DQN-K2 has P=9; the union
        // must cover divisors of both (max is bounded by mesh/capacity cuts
        // but at least the GLB slot keeps full divisor reach)
        let p = ranges[Dim::P.index()];
        assert!(p.count > 0);
        assert_eq!(p.min, 1);
        assert_eq!(p.max, 20, "GLB slot keeps the full divisor lattice");
        // an empty space collapses the pinned dimension's count to zero
        let ranges = pruned.admissible_ranges(&empty_for_dqn_k1());
        assert!(
            ranges.iter().any(|r| r.count == 0),
            "provably-empty layer must flag a dimension: {ranges:?}"
        );
    }
}
