//! Cross-space pruning: certify the hardware design space against a target
//! layer set *before* any simulator evaluation.
//!
//! The joint hw/sw space is profitable exactly where the two sub-spaces
//! interact (CODEBench, Tuli et al. 2022; the semi-decoupled search of Lu
//! et al. 2022 — both named in ROADMAP's feasibility-engine entry): a
//! hardware configuration whose *mapping space* is empty for some layer of
//! the target network can never win, yet the plain hardware search only
//! discovers that by paying a full inner software search for the config.
//! [`PrunedHwSpace`] closes the gap by reusing the PR-4 constraint
//! propagation: for a candidate [`HwConfig`] it computes, per target layer,
//! the feasibility certificate of the (layer, hardware) mapping space —
//! [`SpaceCheck::Constructive`] / [`SpaceCheck::ProvablyEmpty`] /
//! [`SpaceCheck::GlbTight`] — from the divisor lattices and the capacity
//! arithmetic alone, **without sampling a single mapping**.
//!
//! The certificates are exact (property-tested in
//! `rust/tests/prune_soundness.rs`):
//!
//! * `ProvablyEmpty` is a proof — rejection sampling can never find a
//!   mapping there, at any budget (footprints are monotone in the pinned
//!   minimal tile);
//! * `Constructive` is a witness — one constructive draw always succeeds;
//! * `GlbTight` is resolved *exactly* by the exhaustive spatial witness
//!   search (`FeasibleSampler::certified_empty`): either a feasibility
//!   witness exists, or emptiness is proven — so tight spaces are pruned
//!   precisely when no mapping exists, never on a guess.
//!
//! [`PrunedHwSpace::sample_valid`] therefore rejects hardware points whose
//! mapping space is provably empty for any target layer before they ever
//! reach the simulator (telemetry: `prune_certificates` /
//! `prune_rejections` through [`telemetry`] into `coordinator::metrics`),
//! and [`PrunedHwSpace::admissible_ranges`] reports the per-dimension
//! lattice-admissible factor ranges a configuration leaves the software
//! search — the same ranges round-BO's lattice box is derived from.
#![deny(clippy::style)]

use std::collections::BTreeSet;

use crate::model::arch::{HwConfig, Resources};
use crate::model::workload::Layer;
use crate::space::feasible::{telemetry, FactorRange, FeasibleSampler, SpaceCheck};
use crate::space::hw_space::HwSpace;
use crate::util::rng::Rng;

/// How many provably-empty candidates [`PrunedHwSpace::sample_valid`]
/// discards before giving up and handing back an uncertified draw (the
/// inner software search then reports the unknown-constraint violation,
/// exactly as it would have pre-pruning — liveness is never traded for the
/// optimization).
const MAX_PRUNE_REJECTS: u32 = 256;

/// Per-layer feasibility certificates of one hardware configuration
/// against a target layer set, in layer order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HwCertificate {
    /// Propagation start check per layer.
    pub per_layer: Vec<SpaceCheck>,
    /// Exact per-layer emptiness: `true` for a pinned-overflow proof *and*
    /// for a GLB-tight space whose exhaustive spatial witness search proved
    /// no mapping exists.
    pub empty: Vec<bool>,
}

impl HwCertificate {
    /// No target layer's mapping space is provably empty: the configuration
    /// may reach the simulator. (GLB-tight layers pass exactly when a
    /// feasibility witness exists.)
    pub fn admits_all(&self) -> bool {
        !self.empty.iter().any(|&e| e)
    }

    /// Every target layer's space is constructive: the inner search is
    /// guaranteed one-draw candidate generation on all of them.
    pub fn constructive_for_all(&self) -> bool {
        self.per_layer.iter().all(|c| *c == SpaceCheck::Constructive)
    }

    /// Number of target layers whose mapping space is provably empty.
    pub fn empty_layers(&self) -> usize {
        self.empty.iter().filter(|&&e| e).count()
    }
}

/// The hardware design space pruned against a target layer set. Construct
/// one per co-design run (the driver does) and share it with the hardware
/// search loops; an empty layer set ([`PrunedHwSpace::unconstrained`])
/// degrades to the plain constructive sampler for synthetic objectives.
#[derive(Clone, Debug)]
pub struct PrunedHwSpace {
    inner: HwSpace,
    layers: Vec<Layer>,
}

impl PrunedHwSpace {
    pub fn new(resources: Resources, layers: Vec<Layer>) -> Self {
        PrunedHwSpace { inner: HwSpace::new(resources), layers }
    }

    /// A pruned space with no target layers: every certificate passes
    /// trivially. Used by searches over synthetic objectives (tests,
    /// benches) where no workload exists to prune against.
    pub fn unconstrained(resources: Resources) -> Self {
        PrunedHwSpace::new(resources, Vec::new())
    }

    /// The underlying (unpruned) hardware space.
    pub fn space(&self) -> &HwSpace {
        &self.inner
    }

    pub fn resources(&self) -> &Resources {
        &self.inner.resources
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Per-layer feasibility certificates of `hw`, from the propagation
    /// start check and — on GLB-tight layers — the exhaustive spatial
    /// witness search (no mapping is ever *sampled*). Cost: one
    /// divisor-lattice build and one capacity evaluation per layer;
    /// tight layers add the (mesh-bounded, small) witness enumeration.
    pub fn certify(&self, hw: &HwConfig) -> HwCertificate {
        telemetry::record_certificates(self.layers.len() as u64);
        let mut per_layer = Vec::with_capacity(self.layers.len());
        let mut empty = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let fs = self.layer_sampler(layer, hw);
            per_layer.push(fs.check());
            empty.push(fs.certified_empty());
        }
        HwCertificate { per_layer, empty }
    }

    /// Short-circuiting admission test for the sampling hot path: stops at
    /// the first layer with a proven-empty mapping space (recording only
    /// the certificates it actually computed).
    pub fn admits(&self, hw: &HwConfig) -> bool {
        for layer in &self.layers {
            telemetry::record_certificates(1);
            if self.layer_sampler(layer, hw).certified_empty() {
                return false;
            }
        }
        true
    }

    fn layer_sampler(&self, layer: &Layer, hw: &HwConfig) -> FeasibleSampler {
        FeasibleSampler::new(layer.clone(), hw.clone(), self.inner.resources.clone())
    }

    /// One hardware configuration that satisfies the known Fig. 7
    /// constraints by construction *and* whose mapping space is not provably
    /// empty for any target layer, plus the raw draws it cost (rejected
    /// candidates included — they cost one draw each but zero simulator
    /// evaluations, which is the point). After [`MAX_PRUNE_REJECTS`]
    /// consecutive empty certificates the next uncertified draw is returned
    /// so callers always make progress; the inner search then surfaces the
    /// unknown constraint as before.
    pub fn sample_valid(&self, rng: &mut Rng) -> (HwConfig, u64) {
        let mut draws = 0u64;
        for _ in 0..MAX_PRUNE_REJECTS {
            let (hw, d) = self.inner.sample_valid(rng);
            draws += d;
            if self.admits(&hw) {
                return (hw, draws);
            }
            telemetry::record_prune_rejection();
        }
        let (hw, d) = self.inner.sample_valid(rng);
        (hw, draws + d)
    }

    /// Per loop dimension, the union over all target layers (and all four
    /// constructive slots) of the lattice-admissible blocking factors `hw`
    /// leaves the software search — the pruned space's per-dimension
    /// admissible report. `count` is the number of distinct admissible
    /// values in the union; a zero count marks a dimension some layer can
    /// not block at all (the provably-empty signature).
    pub fn admissible_ranges(&self, hw: &HwConfig) -> [FactorRange; 6] {
        let mut unions: [BTreeSet<u64>; 6] = std::array::from_fn(|_| BTreeSet::new());
        let mut emptied = [false; 6];
        for layer in &self.layers {
            let fs = self.layer_sampler(layer, hw);
            let sets = fs.lattice_sets();
            // slot-major: each entry holds the six per-dimension value sets
            // of one constructive slot
            for per_slot in &sets {
                for (i, vals) in per_slot.iter().enumerate() {
                    if vals.is_empty() {
                        emptied[i] = true;
                    }
                    unions[i].extend(vals.iter().copied());
                }
            }
        }
        std::array::from_fn(|i| {
            let set = &unions[i];
            match (set.first(), set.last()) {
                (Some(&min), Some(&max)) if !emptied[i] => {
                    FactorRange { min, max, count: set.len() }
                }
                (Some(&min), Some(&max)) => FactorRange { min, max, count: 0 },
                _ => FactorRange { min: 1, max: 1, count: 0 },
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch::DataflowOpt;
    use crate::model::workload::Dim;
    use crate::workloads::eyeriss::eyeriss_hw;
    use crate::workloads::specs::dqn;

    fn dqn_pruned() -> PrunedHwSpace {
        PrunedHwSpace::new(Resources::eyeriss_168(), dqn().layers)
    }

    /// A configuration whose pinned 8x8 DQN-K1 weight tile (64 words)
    /// overflows the weight sub-buffer: provably empty for DQN-K1.
    fn empty_for_dqn_k1() -> HwConfig {
        let mut hw = eyeriss_hw(168);
        hw.df_filter_w = DataflowOpt::FullAtPe;
        hw.df_filter_h = DataflowOpt::FullAtPe;
        hw.lb_weights = 32;
        hw.lb_inputs = 172;
        hw.lb_outputs = 16;
        hw
    }

    #[test]
    fn eyeriss_is_certified_constructive_for_dqn() {
        let pruned = dqn_pruned();
        let cert = pruned.certify(&eyeriss_hw(168));
        assert_eq!(cert.per_layer.len(), 2);
        assert!(cert.admits_all());
        assert!(cert.constructive_for_all());
        assert_eq!(cert.empty_layers(), 0);
        assert!(pruned.admits(&eyeriss_hw(168)));
    }

    #[test]
    fn pinned_overflow_is_certified_empty_and_rejected() {
        let pruned = dqn_pruned();
        let hw = empty_for_dqn_k1();
        assert_eq!(hw.check(pruned.resources()), Ok(()), "fixture must be Fig.7-valid");
        let cert = pruned.certify(&hw);
        assert_eq!(cert.per_layer[0], SpaceCheck::ProvablyEmpty, "DQN-K1 must be empty");
        assert!(!cert.admits_all());
        assert!(cert.empty_layers() >= 1);
        assert!(!pruned.admits(&hw));
    }

    #[test]
    fn unconstrained_space_admits_everything() {
        let pruned = PrunedHwSpace::unconstrained(Resources::eyeriss_168());
        let cert = pruned.certify(&empty_for_dqn_k1());
        assert!(cert.per_layer.is_empty());
        assert!(cert.admits_all());
        let mut rng = Rng::seed_from_u64(1);
        // degrades to the plain constructive sampler: one draw per config
        for _ in 0..50 {
            let (hw, draws) = pruned.sample_valid(&mut rng);
            assert_eq!(draws, 1);
            assert_eq!(hw.check(pruned.resources()), Ok(()));
        }
    }

    #[test]
    fn pruned_sampling_rejects_empty_configs_before_evaluation() {
        let pruned = dqn_pruned();
        let before = telemetry::snapshot();
        let mut rng = Rng::seed_from_u64(7);
        let mut total_draws = 0u64;
        for _ in 0..200 {
            let (hw, draws) = pruned.sample_valid(&mut rng);
            total_draws += draws;
            // every returned configuration is admissible...
            assert!(pruned.certify(&hw).admits_all());
            assert_eq!(hw.check(pruned.resources()), Ok(()));
        }
        // ...and the 8x8 DQN-K1 filters make double-FullAtPe small-buffer
        // draws common enough that the pruner must actually have fired
        let delta = telemetry::snapshot().since(&before);
        assert!(delta.prune_rejections >= 1, "no rejection in 200 samples: {delta:?}");
        assert!(total_draws > 200, "rejected draws must be accounted: {total_draws}");
        assert!(delta.prune_certificates >= 200, "certificates must be counted: {delta:?}");
    }

    #[test]
    fn glb_tight_layers_are_pruned_exactly() {
        // the shared hand-computed GLB-tight fixture (see
        // `space::feasible::fixtures`) as a one-layer target set: capacity
        // 12 keeps a witness, capacity 11 is proven empty — the pruner must
        // track that boundary exactly
        let fixture = crate::space::feasible::fixtures::tight_fixture;
        let (layer, hw, res) = fixture(12);
        let feasible = PrunedHwSpace::new(res, vec![layer]);
        let cert = feasible.certify(&hw);
        assert_eq!(cert.per_layer, vec![SpaceCheck::GlbTight]);
        assert!(cert.admits_all(), "tight-but-feasible must not be pruned");
        let (layer, hw, res) = fixture(11);
        let empty = PrunedHwSpace::new(res, vec![layer]);
        let cert = empty.certify(&hw);
        assert_eq!(cert.per_layer, vec![SpaceCheck::GlbTight]);
        assert!(!cert.admits_all(), "tight-and-proven-empty must be pruned");
        assert_eq!(cert.empty_layers(), 1);
    }

    #[test]
    fn admissible_ranges_union_layers_and_flag_empty_dims() {
        let pruned = dqn_pruned();
        let ranges = pruned.admissible_ranges(&eyeriss_hw(168));
        // P spans both layers: DQN-K1 has P=20, DQN-K2 has P=9; the union
        // must cover divisors of both (max is bounded by mesh/capacity cuts
        // but at least the GLB slot keeps full divisor reach)
        let p = ranges[Dim::P.index()];
        assert!(p.count > 0);
        assert_eq!(p.min, 1);
        assert_eq!(p.max, 20, "GLB slot keeps the full divisor lattice");
        // an empty space collapses the pinned dimension's count to zero
        let ranges = pruned.admissible_ranges(&empty_for_dqn_k1());
        assert!(
            ranges.iter().any(|r| r.count == 0),
            "provably-empty layer must flag a dimension: {ranges:?}"
        );
    }
}
